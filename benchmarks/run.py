"""Benchmark suite entry point — one module per paper table/figure.

Prints ``name,us_per_call,derived`` summary CSV lines (full tables land
in artifacts/bench/*.csv)::

  PYTHONPATH=src python -m benchmarks.run [--fast]
"""
from __future__ import annotations

import sys
import time


def _summary(name, t0, derived):
    us = (time.time() - t0) * 1e6
    print(f"{name},{us:.0f},{derived}")


def main() -> None:
    fast = "--fast" in sys.argv
    from . import (fig09_isolated, fig12_memory, fig14_e2e, fig15_ablation,
                   fig16_dse, fig17_granularity, fig18_scalability,
                   jax_moe_strategies, roofline)

    print("name,us_per_call,derived")

    t0 = time.time()
    rows = fig09_isolated.run(timeline=not fast)
    sp = [r[4] for r in rows if r[2] == "fse_dp_paired"]
    _summary("fig09_isolated_layer", t0,
             f"fse_dp_paired speedup vs EP: min={min(sp):.2f}x "
             f"mean={sum(sp)/len(sp):.2f}x max={max(sp):.2f}x")

    t0 = time.time()
    rows = fig12_memory.run()
    sav = [r[3] for r in rows if r[1] == "fse_dp_paired"]
    _summary("fig12_memory", t0,
             f"memory saving vs EP: {min(sav):.1f}%..{max(sav):.1f}%")

    t0 = time.time()
    rows = fig14_e2e.run(iterations=6 if fast else 12,
                         layer_sample=4 if fast else 6)
    sp = [r[4] for r in rows if r[1] == "fse_dp_paired" and r[2] == 0.2]
    _summary("fig14_e2e", t0,
             f"e2e speedup vs EP @20% slack: mean={sum(sp)/len(sp):.2f}x")

    t0 = time.time()
    rows = fig15_ablation.run()
    _summary("fig15_ablation", t0,
             "A1..A5 utilization: " + " ".join(
                 f"{r[1]}={r[4]:.3f}" for r in rows if r[0] == "qwen3-a3b"))

    if not fast:
        t0 = time.time()
        fig16_dse.run()
        _summary("fig16_dse", t0, "see artifacts/bench/fig16_dse.csv")

        t0 = time.time()
        fig17_granularity.run()
        _summary("fig17_granularity", t0, "see artifacts/bench/fig17_granularity.csv")

    t0 = time.time()
    rows = fig18_scalability.run()
    u = {(r[0], r[1]): r[2] for r in rows}
    _summary("fig18_scalability", t0,
             f"util 2x2->4x4: ep {u[('2x2','ep')]:.3f}->{u[('4x4','ep')]:.3f} "
             f"fse_dp {u[('2x2','fse_dp_paired')]:.3f}->{u[('4x4','fse_dp_paired')]:.3f}")

    t0 = time.time()
    try:
        rows = jax_moe_strategies.run()
        fse = next(r for r in rows if r[0] == "fse_dp")
        ep = next(r for r in rows if r[0] == "ep")
        _summary("jax_moe_strategies", t0,
                 f"fse_dp a2a={fse[3]}B permute={fse[4]}B | ep a2a={ep[3]}B")
    except Exception as e:  # pragma: no cover
        _summary("jax_moe_strategies", t0, f"SKIPPED ({e})")

    t0 = time.time()
    rows = roofline.run()
    ok = [r for r in rows if r[3] == "ok"]
    _summary("roofline", t0,
             f"{len(ok)} compiled cells aggregated (artifacts/bench/roofline.csv)")


if __name__ == "__main__":
    main()
