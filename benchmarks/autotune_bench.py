"""Cost-model-vs-simulator validation sweep for the FSE-DP autotuner.

For every (B, S, E, d_expert, P) point of ``autotune.VALIDATION_SWEEP``
(low-batch decode, prefill, and batch-heavy decode regimes on the
Table-I chiplet arrays) this bench records, per execution mode:

* the analytical cost model's predicted seconds (``autotune.plan_moe``
  with the mode forced, so micro-slices are optimized per mode), and
* the step-level chiplet simulation (``sim.modes.simulate_mode``) as the
  measured referee,

plus the top-choice agreement fraction (the acceptance gate is >= 0.8),
the trajectory-scheduler simulation (``sim.engine.simulate_layer``,
strategy ``fse_dp_paired``) for cross-reference, and — unless
``--no-measure`` — wall-clock kernel-tile timings from the measured
autotune path on a few tiny shapes.  Emits
``artifacts/bench/BENCH_autotune.json``; a committed copy under
``benchmarks/baselines/`` is the CI regression baseline.

Usage:
  PYTHONPATH=src python benchmarks/autotune_bench.py [--no-measure]
      [--out DIR]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "bench")

D_MODEL = 512


def _hw(P):
    from repro.sim.hardware import scaled
    return {2: scaled(1, 2), 4: scaled(2, 2), 8: scaled(2, 4)}[P]


def sweep_rows():
    from repro.configs.base import MoEConfig
    from repro.core import autotune as at
    from repro.sim import modes as sim_modes
    from repro.sim.engine import simulate_layer
    from repro.sim.hardware import ModelSpec
    from repro.sim.workload import make_layer_workload, make_requests

    rows = []
    agree = 0
    for (B, S, E, de, P) in at.VALIDATION_SWEEP:
        hw = _hw(P)
        profile = at.HardwareProfile.from_chiplet(hw)
        spec = ModelSpec("sweep", D_MODEL, de, E, 2)
        moe = MoEConfig(num_experts=E, top_k=2, d_expert=de)

        plan = at.plan_moe(B, S, D_MODEL, moe, "swiglu", P,
                           profile=profile, level="analytic")
        predicted = {}
        for mode in at.feasible_modes(B, S, P):
            predicted[mode] = at.plan_moe(
                B, S, D_MODEL, moe, "swiglu", P, profile=profile,
                level="analytic", mode=mode).predicted_s
        simulated = sim_modes.rank_modes(hw, spec, B * S, B=B, S=S)
        sim_best = min(simulated, key=simulated.get)
        ok = plan.mode == sim_best
        agree += ok

        # trajectory-scheduler cross-reference (same hardware model)
        reqs = make_requests(B * S, hw.num_chiplets, seed=0)
        wl = make_layer_workload(spec, reqs, hw.num_chiplets, 0, seed=0)
        engine_s = simulate_layer(hw, spec, wl, "fse_dp_paired",
                                  micro_slices=plan.micro_slices).latency

        rows.append({
            "B": B, "S": S, "E": E, "d_expert": de, "P": P,
            "chosen": plan.mode, "micro_slices": plan.micro_slices,
            "sim_best": sim_best, "agree": bool(ok),
            "predicted_s": {k: round(v, 9) for k, v in predicted.items()},
            "simulated_s": {k: round(v, 9) for k, v in simulated.items()},
            "engine_fse_dp_s": round(engine_s, 9),
            "plan_vmem_bytes": plan.vmem_bytes,
        })
        print(f"B={B:4d} S={S:4d} E={E:3d} de={de:5d} P={P} "
              f"chosen={plan.mode:6s} sim_best={sim_best:6s} "
              f"{'OK' if ok else 'MISS'}")
    return rows, agree / len(rows)


def measure_tiles():
    """Wall-clock the measured-autotune path on tiny kernel shapes."""
    from repro.core import autotune as at
    out = []
    for (E, C, d, m, act) in ((2, 8, 32, 16, "swiglu"),
                              (4, 16, 64, 32, "swiglu"),
                              (4, 16, 64, 32, "gelu")):
        entry = at.measured_kernel_tiles(E, C, d, m, act, dtype_bytes=4,
                                         reps=2)
        out.append({"E": E, "C": C, "d": d, "m": m, "activation": act,
                    "best_opts": entry["opts"],
                    "measured_ms": round(entry["ms"], 4),
                    "analytic_predicted_s": entry["analytic_s"],
                    "xla_flops": entry.get("flops", 0.0)})
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--no-measure", action="store_true",
                    help="skip wall-clock kernel-tile timing")
    ap.add_argument("--out", default=ART)
    args = ap.parse_args(argv)

    import jax

    rows, agreement = sweep_rows()
    tiles = [] if args.no_measure else measure_tiles()
    print(f"# mode-rank agreement: {agreement:.3f} over {len(rows)} points")

    payload = {
        "bench": "autotune_costmodel_vs_simulator",
        "backend": jax.default_backend(),
        "jax": jax.__version__,
        "d_model": D_MODEL,
        "agreement": agreement,
        "unix_time": int(time.time()),
        "rows": rows,
        "tile_measurements": tiles,
    }
    os.makedirs(args.out, exist_ok=True)
    path = os.path.join(args.out, "BENCH_autotune.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# {len(rows)} sweep points -> {os.path.relpath(path)}")
    return path


if __name__ == "__main__":
    main()
