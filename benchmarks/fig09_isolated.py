"""Paper Fig. 9: single-MoE-layer latency — EP / Hydra / FSE-DP (A2) /
FSE-DP+paired (A3) across the four Table-I models × token counts.

Also emits the Fig. 11 utilization-fluctuation trace with --timeline.
"""
from __future__ import annotations

import numpy as np

from repro.sim import PROTOTYPE_2X2, PAPER_SPECS, iteration_workloads, simulate_layer
from .common import emit

TOKENS = (16, 64, 256, 1024)
STRATS = ("ep", "hydra", "fse_dp", "fse_dp_paired")
SEEDS = (0, 1, 2)     # ~ datasets (wikitext-2 / c4 style trace variation)


def run(timeline: bool = False):
    hw = PROTOTYPE_2X2
    rows = []
    for mname, spec in PAPER_SPECS.items():
        for toks in TOKENS:
            lat = {s: [] for s in STRATS}
            for seed in SEEDS:
                wl = iteration_workloads(spec, tokens_per_iter=toks,
                                         num_chiplets=hw.num_chiplets,
                                         seed=seed)[0]
                for s in STRATS:
                    lat[s].append(simulate_layer(hw, spec, wl, s).latency)
            base = np.mean(lat["ep"])
            for s in STRATS:
                m = float(np.mean(lat[s]))
                rows.append([mname, toks, s, round(m * 1e6, 1),
                             round(base / m, 3)])
    emit("fig09_isolated_layer",
         rows, ["model", "tokens_per_iter", "strategy", "latency_us",
                "speedup_vs_ep"])
    if timeline:
        wl = iteration_workloads(PAPER_SPECS["qwen3-a3b"], tokens_per_iter=256,
                                 num_chiplets=hw.num_chiplets, seed=0)[0]
        r = simulate_layer(hw, PAPER_SPECS["qwen3-a3b"], wl, "fse_dp_paired",
                           record_timeline=True)
        trows = [[round(t * 1e6, 2), c, kind, round(dur * 1e6, 2)]
                 for t, c, kind, dur in r.timeline[:200]]
        emit("fig11_13_timeline", trows, ["t_us", "chiplet", "event", "dur_us"])
    return rows


def main():
    import sys
    run(timeline="--timeline" in sys.argv)


if __name__ == "__main__":
    main()
