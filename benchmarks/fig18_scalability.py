"""Paper Fig. 18: scalability (utilization) from 2x2 to 4x4 arrays,
Qwen3-A3B on the C4-style trace."""
from __future__ import annotations

import numpy as np

from repro.sim import PAPER_SPECS, iteration_workloads, scaled, simulate_layer
from .common import emit

STRATS = ("ep", "hydra", "fse_dp_paired")


def run():
    spec = PAPER_SPECS["qwen3-a3b"]
    rows = []
    for rows_cols in ((2, 2), (3, 3), (4, 4)):
        hw = scaled(*rows_cols)
        for strat in STRATS:
            us = []
            for seed in (0, 1, 2):
                wl = iteration_workloads(spec, tokens_per_iter=256,
                                         num_chiplets=hw.num_chiplets,
                                         seed=seed)[0]
                us.append(simulate_layer(hw, spec, wl, strat).utilization)
            rows.append([f"{rows_cols[0]}x{rows_cols[1]}", strat,
                         round(float(np.mean(us)), 4)])
    emit("fig18_scalability", rows, ["array", "strategy", "utilization"])
    return rows


def main():
    run()


if __name__ == "__main__":
    main()
