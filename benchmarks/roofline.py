"""§Roofline: aggregate the dry-run artifacts into the per-(arch×shape×mesh)
three-term roofline table (EXPERIMENTS.md §Roofline reads this CSV).

Terms (seconds): compute = FLOPs/(chips·197T) · memory = bytes/(chips·819G)
· collective = coll_bytes/(chips·50G).  FLOPs/bytes are the CPU
cost_analysis values scaled by scan trip count (the CPU backend counts a
while body once — see DESIGN.md §8); MODEL_FLOPS/HLO_FLOPS flags
remat/dispatch waste.
"""
from __future__ import annotations

import glob
import json
import os

from .common import emit

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def load(art_dir: str = ART, tag: str = ""):
    recs = []
    for f in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        r = json.load(open(f))
        if (r.get("tag") or "") != tag:
            continue
        recs.append(r)
    return recs


def rows_from(recs):
    rows = []
    for r in recs:
        if r["status"] != "ok":
            rows.append([r["arch"], r["shape"], r["mesh"], r["status"],
                         r.get("reason") or r.get("error", "")[:60],
                         "", "", "", "", "", "", ""])
            continue
        rl = r["roofline"]
        trips = r.get("scan_trips", 1)
        hlo_flops = r["cost_flops_per_device"] * trips * r["chips"]
        ratio = r["model_flops"] / hlo_flops if hlo_flops else 0.0
        rows.append([
            r["arch"], r["shape"], r["mesh"], "ok", rl["dominant"],
            f"{rl['compute_s']:.3e}", f"{rl['memory_s']:.3e}",
            f"{rl['collective_s']:.3e}",
            f"{r['per_device_bytes'] / 2**30:.2f}",
            f"{r['model_flops']:.3e}", f"{hlo_flops:.3e}", f"{ratio:.3f}",
        ])
    return rows


HEADER = ["arch", "shape", "mesh", "status", "dominant/skip-reason",
          "compute_s", "memory_s", "collective_s", "mem_GiB_per_dev",
          "model_flops", "hlo_flops_scaled", "model/hlo"]


def run(tag: str = ""):
    rows = rows_from(load(tag=tag))
    emit("roofline" + (f"_{tag}" if tag else ""), rows, HEADER)
    return rows


def main():
    import sys
    run(sys.argv[1] if len(sys.argv) > 1 else "")


if __name__ == "__main__":
    main()
