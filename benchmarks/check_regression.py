"""Benchmark-regression gate: fresh artifacts vs committed baselines.

Compares ``artifacts/bench/*.json`` (produced by this run's
``kernel_bench.py`` / ``autotune_bench.py``) against the committed
``benchmarks/baselines/*.json`` and exits non-zero on regression:

* BENCH_autotune.json — deterministic metrics: the mode-rank agreement
  must stay >= --min-agreement (acceptance floor 0.8), and the cost
  model's predicted per-mode seconds must not drift slower than the
  baseline by more than --tolerance on any sweep point (catches cost
  model regressions exactly, no timing noise).
* BENCH_streamed_moe.json — timing metric, compared machine-relatively:
  each row's pallas_ms/einsum_ms ratio (both sides measured in the same
  run, so host speed cancels) against the baseline row's ratio; FAIL if
  the *median* relative slowdown across matched rows exceeds
  --tolerance (median absorbs per-row CI jitter).  Once the baseline
  carries the quantized columns, the int8-streaming block is gated
  deterministically: expert-weight bytes must undercut bf16 by >= 40%
  and oracle parity must stay within 2% rel Frobenius
  (docs/quantization.md).
* BENCH_moe_strategies.json — deterministic metrics: the cross-family
  ``auto`` planner must pick the same family as the baseline, and each
  strategy row's HLO collective bytes must stay within --tolerance
  (byte counts are exact per jax version, so drift means the lowering
  or the registry dispatch genuinely changed).
* BENCH_serving.json — deterministic metrics on two clocks: the
  iteration-counted latency percentiles and exact token/completion
  counts, plus the modeled chiplet-array-seconds percentiles and their
  agreement ratio against the ``sim.modes.replay_trace`` referee
  (within 5%).  The ``prefix_mix`` block gates prefix caching: outputs
  must stay bit-identical to the pool-off run, and the prefill-compute
  savings must clear the 40% floor without regressing against the
  baseline.  The wall-clock and state-pool blocks are informational,
  never gated (see docs/benchmarks.md).

Usage:
  PYTHONPATH=src python benchmarks/check_regression.py \
      [--baseline-dir benchmarks/baselines] [--current-dir artifacts/bench] \
      [--tolerance 0.25] [--min-agreement 0.8]
"""
from __future__ import annotations

import argparse
import json
import os
import statistics
import sys

HERE = os.path.dirname(os.path.abspath(__file__))


def _load(path):
    with open(path) as f:
        return json.load(f)


def check_autotune(base, cur, tol, min_agreement, failures):
    if cur["agreement"] < min_agreement:
        failures.append(f"BENCH_autotune: agreement {cur['agreement']:.3f} "
                        f"< floor {min_agreement}")
    # independent drift gate: the sweep is deterministic, so losing more
    # than one point relative to the committed baseline means the cost
    # model genuinely changed (one point of slack tolerates a near-tie
    # flipping under a legitimate improvement)
    slack = 1.0 / max(1, len(cur["rows"]))
    if cur["agreement"] < base["agreement"] - slack - 1e-9:
        failures.append(f"BENCH_autotune: agreement regressed "
                        f"{base['agreement']:.3f} -> {cur['agreement']:.3f} "
                        f"(> one sweep point)")
    base_rows = {(r["B"], r["S"], r["E"], r["d_expert"], r["P"]): r
                 for r in base["rows"]}
    matched = 0
    for r in cur["rows"]:
        key = (r["B"], r["S"], r["E"], r["d_expert"], r["P"])
        b = base_rows.get(key)
        if b is None:
            continue
        matched += 1
        for mode, t in r["predicted_s"].items():
            bt = b["predicted_s"].get(mode)
            if bt and t > bt * (1 + tol):
                failures.append(
                    f"BENCH_autotune {key} {mode}: predicted "
                    f"{bt:.3e}s -> {t:.3e}s (+{t / bt - 1:.0%} > {tol:.0%})")
    if not matched:
        failures.append("BENCH_autotune: no baseline rows matched the sweep "
                        "— refresh benchmarks/baselines/")
    print(f"BENCH_autotune: agreement={cur['agreement']:.3f} "
          f"(baseline {base['agreement']:.3f}), {matched} rows matched")


def check_streamed_moe(base, cur, tol, failures):
    def key(r):
        return (r["config"], r["E"], r["d_model"], r["d_expert"],
                r["slice_div"], r["C"], r["activation"])

    base_rows = {key(r): r for r in base["rows"]}
    # gate both kernel branches: default tiles (pallas_ms) and the
    # autotune-scheduled tiles (autotuned_ms) every model path dispatches
    # through — each normalized by the same-run einsum time so host speed
    # cancels
    slowdowns = {"pallas_ms": [], "autotuned_ms": []}
    for r in cur["rows"]:
        b = base_rows.get(key(r))
        if b is None or not b.get("einsum_ms"):
            continue
        for col in slowdowns:
            if not b.get(col) or not r.get(col):
                continue
            cur_ratio = r[col] / max(r["einsum_ms"], 1e-9)
            base_ratio = b[col] / max(b["einsum_ms"], 1e-9)
            slowdowns[col].append(cur_ratio / max(base_ratio, 1e-9) - 1.0)
    if not slowdowns["pallas_ms"]:
        failures.append("BENCH_streamed_moe: no baseline rows matched — "
                        "refresh benchmarks/baselines/")
        return
    for col, vals in slowdowns.items():
        if not vals:
            continue
        med = statistics.median(vals)
        print(f"BENCH_streamed_moe[{col}]: median kernel-vs-einsum slowdown "
              f"{med:+.1%} over {len(vals)} matched rows (tolerance "
              f"{tol:.0%})")
        if med > tol:
            failures.append(f"BENCH_streamed_moe[{col}]: median relative "
                            f"slowdown {med:+.1%} exceeds {tol:.0%}")
    check_quant_block(base, cur, failures)


# quantized-streaming acceptance: int8 expert-weight DDR bytes (weights +
# per-channel scale rows) must undercut the bf16 stream by >= 40%, and
# the quantized oracle must stay within the documented 2% relative
# Frobenius error of the fp32 reference (docs/quantization.md)
QUANT_BYTES_FLOOR = 0.40
QUANT_REL_ERR_CEIL = 0.02


def check_quant_block(base, cur, failures):
    """Quantized-streaming gate — active only once the committed
    baseline carries the quantized columns (older baselines skip it).
    Both gated metrics are deterministic: the bytes reduction is pure
    shape arithmetic and the parity error is a fixed-seed oracle
    comparison, so no timing noise enters."""
    if not any("quant_bytes_reduction" in r for r in base["rows"]):
        return
    rows = [r for r in cur["rows"] if "quant_bytes_reduction" in r]
    if not rows:
        failures.append("BENCH_streamed_moe[quant]: quantized columns "
                        "disappeared — the int8 streaming branch is gated")
        return
    worst_red = min(r["quant_bytes_reduction"] for r in rows)
    worst_err = max(r["quant_rel_err"] for r in rows)
    if worst_red < QUANT_BYTES_FLOOR:
        bad = [r for r in rows
               if r["quant_bytes_reduction"] < QUANT_BYTES_FLOOR][0]
        failures.append(
            f"BENCH_streamed_moe[quant]: bytes reduction "
            f"{worst_red:.1%} < floor {QUANT_BYTES_FLOOR:.0%} "
            f"({bad['config']} m={bad['m_slice']})")
    if worst_err > QUANT_REL_ERR_CEIL:
        bad = [r for r in rows if r["quant_rel_err"] > QUANT_REL_ERR_CEIL][0]
        failures.append(
            f"BENCH_streamed_moe[quant]: int8 oracle parity "
            f"{worst_err:.4f} > {QUANT_REL_ERR_CEIL} rel Frobenius "
            f"({bad['config']} m={bad['m_slice']})")
    print(f"BENCH_streamed_moe[quant]: {len(rows)} rows, worst bytes "
          f"reduction {worst_red:.1%} (floor {QUANT_BYTES_FLOOR:.0%}), "
          f"worst rel err {worst_err:.4f} (ceil {QUANT_REL_ERR_CEIL})")


def check_moe_strategies(base, cur, tol, failures):
    if cur.get("auto_family") != base.get("auto_family"):
        failures.append(f"BENCH_moe_strategies: auto planner family "
                        f"changed {base.get('auto_family')} -> "
                        f"{cur.get('auto_family')} — refresh the baseline "
                        f"if intentional")
    base_rows = {r["strategy"]: r for r in base["rows"]}
    matched = 0
    for r in cur["rows"]:
        b = base_rows.get(r["strategy"])
        if b is None:
            continue
        matched += 1
        for col in ("coll_total", "weight_bytes_per_device"):
            bv, cv = b.get(col, 0), r.get(col, 0)
            if bv and abs(cv - bv) > bv * tol:
                failures.append(
                    f"BENCH_moe_strategies {r['strategy']}.{col}: "
                    f"{bv} -> {cv} ({cv / bv - 1:+.0%} > ±{tol:.0%})")
    if not matched:
        failures.append("BENCH_moe_strategies: no baseline rows matched — "
                        "refresh benchmarks/baselines/")
    print(f"BENCH_moe_strategies: auto={cur.get('auto_family')} "
          f"(baseline {base.get('auto_family')}), {matched} rows matched")
    check_skewed_schedules(base, cur, tol, failures)
    check_hybrid_block(base, cur, failures)


HYBRID_AGREE_FLOOR = 0.8


def check_hybrid_block(base, cur, failures):
    """Two-tier hybrid gate — active only once the committed baseline
    carries the hybrid sweep (older baselines skip it).  Everything
    here is deterministic host-side simulation, so no timing noise:
    the cost model must agree with the chiplet referee on >=80% of the
    committed sweep, the sweep must not be degenerate (hybrid, EP and
    FSE-DP each win somewhere), and the load-aware fast-tier partition
    must beat the static id-prefix on every skewed point."""
    if not base.get("hybrid"):
        return
    hybrid = cur.get("hybrid") or {}
    sweep = hybrid.get("sweep") or []
    partition = hybrid.get("partition") or []
    if not sweep:
        failures.append("BENCH_moe_strategies[hybrid]: sweep rows "
                        "disappeared — rerun jax_moe_strategies.py")
        return
    frac = sum(r["agree"] for r in sweep) / len(sweep)
    if frac < HYBRID_AGREE_FLOOR:
        bad = [r for r in sweep if not r["agree"]]
        failures.append(
            f"BENCH_moe_strategies[hybrid]: cost/sim agreement "
            f"{frac:.0%} < {HYBRID_AGREE_FLOOR:.0%} "
            f"({len(bad)} disagreements, first: {bad[0]})")
    winners = {r["sim_family"] for r in sweep}
    for fam in ("hybrid", "ep", "fse_dp"):
        if fam not in winners:
            failures.append(
                f"BENCH_moe_strategies[hybrid]: {fam} wins no simulated "
                f"sweep point (winners: {sorted(winners)}) — the "
                f"family race is degenerate")
    part_wins = sum(r["win"] for r in partition)
    if part_wins < len(partition):
        bad = [r for r in partition if not r["win"]][0]
        failures.append(
            f"BENCH_moe_strategies[hybrid]: dynamic partition beat the "
            f"static top-N on only {part_wins}/{len(partition)} points "
            f"(first loss: E={bad['E']} tokens={bad['tokens']})")
    print(f"BENCH_moe_strategies[hybrid]: agreement {frac:.0%} over "
          f"{len(sweep)} points (floor {HYBRID_AGREE_FLOOR:.0%}), sim "
          f"winners {sorted(winners)}, dynamic partition wins "
          f"{part_wins}/{len(partition)}")


def check_skewed_schedules(base, cur, tol, failures):
    """Skewed-gating gate (deterministic simulation, no timing noise):
    the dynamic (count-built) schedule must beat the static plan on a
    majority of Zipf points, and neither side's simulated step time may
    drift slower than the committed baseline beyond --tolerance."""
    skewed = cur.get("skewed") or []
    if not skewed:
        failures.append("BENCH_moe_strategies: no skewed-gating rows — "
                        "rerun benchmarks/jax_moe_strategies.py")
        return
    wins = sum(1 for r in skewed if r["win"])
    if wins <= len(skewed) // 2:
        failures.append(f"BENCH_moe_strategies[skewed]: dynamic schedule "
                        f"won only {wins}/{len(skewed)} points "
                        f"(needs a majority)")
    base_rows = {(r["tokens"], r["zipf_s"], r["seed"]): r
                 for r in (base.get("skewed") or [])}
    matched = 0
    for r in skewed:
        b = base_rows.get((r["tokens"], r["zipf_s"], r["seed"]))
        if b is None:
            continue
        matched += 1
        for col in ("static_us", "dynamic_us"):
            if b.get(col) and r[col] > b[col] * (1 + tol):
                failures.append(
                    f"BENCH_moe_strategies[skewed] tokens={r['tokens']} "
                    f"zipf={r['zipf_s']} {col}: {b[col]:.1f} -> "
                    f"{r[col]:.1f}us (+{r[col] / b[col] - 1:.0%} > "
                    f"{tol:.0%})")
    if base.get("skewed") and not matched:
        failures.append("BENCH_moe_strategies[skewed]: no baseline rows "
                        "matched — refresh benchmarks/baselines/")
    print(f"BENCH_moe_strategies[skewed]: dynamic wins {wins}/{len(skewed)}"
          f", {matched} rows matched vs baseline")


def check_serving(base, cur, tol, failures):
    """Serving closed-loop gate.  The benchmark runs on the scheduler's
    iteration clock, so every gated metric is deterministic for a given
    (workload, seed): completion must be total, token/iteration counts
    exact, and the TTFT/TPOT/queue-delay percentiles may not drift
    slower than the committed baseline beyond --tolerance."""
    if cur.get("workload") != base.get("workload"):
        failures.append(f"BENCH_serving: workload changed "
                        f"{base.get('workload')} -> {cur.get('workload')} — "
                        f"refresh benchmarks/baselines/ if intentional")
        return
    want = base["workload"]["requests"]
    if cur.get("completed") != want or cur.get("dropped"):
        failures.append(f"BENCH_serving: {cur.get('completed')}/{want} "
                        f"completed, {cur.get('dropped')} dropped — the "
                        f"closed loop no longer serves every request")
    for col in ("tokens_emitted", "prefill_tokens"):
        if cur.get(col) != base.get(col):
            failures.append(f"BENCH_serving.{col}: {base.get(col)} -> "
                            f"{cur.get(col)} (deterministic count changed)")
    for col in ("iterations", "prefill_chunks"):
        bv, cv = base.get(col, 0), cur.get(col, 0)
        if bv and cv > bv * (1 + tol):
            failures.append(f"BENCH_serving.{col}: {bv} -> {cv} "
                            f"(+{cv / bv - 1:.0%} > {tol:.0%})")
    for metric in ("ttft_iters", "tpot_iters", "queue_delay_iters"):
        for q, bv in (base.get(metric) or {}).items():
            cv = (cur.get(metric) or {}).get(q)
            if cv is None or bv != bv or cv != cv:   # NaN-tolerant
                continue
            if cv > bv * (1 + tol) + 1e-9:
                failures.append(
                    f"BENCH_serving.{metric}.{q}: {bv:.3f} -> {cv:.3f} "
                    f"iters (+{cv / max(bv, 1e-9) - 1:.0%} > {tol:.0%})")
    # modeled chiplet-array seconds — deterministic (Table-I constants,
    # no host timing), so drift is gated exactly like the iteration
    # metrics; wall_clock_informational is deliberately never checked
    bm, cm = base.get("modeled") or {}, cur.get("modeled") or {}
    for metric in ("ttft_s", "tpot_s", "queue_delay_s"):
        for q, bv in (bm.get(metric) or {}).items():
            cv = (cm.get(metric) or {}).get(q)
            if cv is None or bv != bv or cv != cv:   # NaN-tolerant
                continue
            if cv > bv * (1 + tol) + 1e-9:
                failures.append(
                    f"BENCH_serving.modeled.{metric}.{q}: {bv:.3e} -> "
                    f"{cv:.3e}s (+{cv / max(bv, 1e-9) - 1:.0%} > {tol:.0%})")
    if bm:
        if not cm:
            failures.append("BENCH_serving: modeled metrics disappeared — "
                            "the engine's cost-model clock is gated")
        else:
            ratio = cm.get("referee_ratio")
            if ratio is None or abs(ratio - 1.0) > 0.05:
                failures.append(
                    f"BENCH_serving.modeled.referee_ratio: {ratio} — the "
                    f"closed-form clock no longer agrees with the "
                    f"sim.modes.replay_trace referee within 5%")
    print(f"BENCH_serving: {cur.get('completed')} completed in "
          f"{cur.get('iterations')} iterations, ttft p50="
          f"{(cur.get('ttft_iters') or {}).get('p50')} "
          f"(baseline {(base.get('ttft_iters') or {}).get('p50')}), "
          f"modeled ttft p50={(cm.get('ttft_s') or {}).get('p50')}s, "
          f"referee_ratio={cm.get('referee_ratio')}")
    check_prefix_mix(base, cur, failures)


# the acceptance floor for prefix caching on the shared-prefix mix: the
# cached run must spend at least 40% fewer prefill compute tokens
PREFIX_SAVINGS_FLOOR = 0.40


def check_prefix_mix(base, cur, failures):
    """Shared-prefix-mix gate (deterministic: same workload + seed):
    prefix caching must keep outputs bit-identical to the pool-off run,
    clear the 40% prefill-compute-savings floor, and neither the
    savings fraction nor the cache-hit rate may regress against the
    committed baseline."""
    bp, cp = base.get("prefix_mix") or {}, cur.get("prefix_mix") or {}
    if bp and not cp:
        failures.append("BENCH_serving.prefix_mix: block disappeared — the "
                        "prefix-caching run is gated")
        return
    if not cp:
        return
    if not cp.get("outputs_match_pool_off"):
        failures.append("BENCH_serving.prefix_mix: cached outputs diverged "
                        "from the pool-off run — prefix caching broke "
                        "bit-identity")
    sav = cp.get("savings_frac", 0.0)
    if sav < PREFIX_SAVINGS_FLOOR:
        failures.append(f"BENCH_serving.prefix_mix: savings_frac {sav:.2f} "
                        f"< floor {PREFIX_SAVINGS_FLOOR} — shared prefixes "
                        f"are being recomputed")
    if bp.get("workload") == cp.get("workload"):
        for col in ("savings_frac", "cache_hit_rate"):
            bv, cv = bp.get(col), cp.get(col)
            if bv is not None and cv is not None and cv < bv - 1e-9:
                failures.append(f"BENCH_serving.prefix_mix.{col}: "
                                f"{bv:.3f} -> {cv:.3f} (regressed)")
    elif bp:
        failures.append(f"BENCH_serving.prefix_mix: workload changed "
                        f"{bp.get('workload')} -> {cp.get('workload')} — "
                        f"refresh benchmarks/baselines/ if intentional")
    print(f"BENCH_serving.prefix_mix: savings_frac={sav:.3f} "
          f"(baseline {bp.get('savings_frac')}), hit_rate="
          f"{cp.get('cache_hit_rate')}, outputs_match="
          f"{cp.get('outputs_match_pool_off')}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline-dir",
                    default=os.path.join(HERE, "baselines"))
    ap.add_argument("--current-dir",
                    default=os.path.join(HERE, "..", "artifacts", "bench"))
    ap.add_argument("--tolerance", type=float, default=0.25)
    ap.add_argument("--min-agreement", type=float, default=0.8)
    args = ap.parse_args(argv)

    failures: list = []
    checked = 0
    for name, fn in (("BENCH_autotune.json",
                      lambda b, c, f: check_autotune(
                          b, c, args.tolerance, args.min_agreement, f)),
                     ("BENCH_streamed_moe.json",
                      lambda b, c, f: check_streamed_moe(
                          b, c, args.tolerance, f)),
                     ("BENCH_moe_strategies.json",
                      lambda b, c, f: check_moe_strategies(
                          b, c, args.tolerance, f)),
                     ("BENCH_serving.json",
                      lambda b, c, f: check_serving(
                          b, c, args.tolerance, f))):
        bpath = os.path.join(args.baseline_dir, name)
        cpath = os.path.join(args.current_dir, name)
        if not os.path.exists(bpath):
            failures.append(f"missing committed baseline {bpath}")
            continue
        if not os.path.exists(cpath):
            failures.append(f"missing fresh artifact {cpath} — run the "
                            "bench first")
            continue
        fn(_load(bpath), _load(cpath), failures)
        checked += 1

    if failures:
        print(f"\nREGRESSION CHECK FAILED ({len(failures)} issue(s)):")
        for f in failures:
            print(f"  - {f}")
        return 1
    print(f"\nregression check OK ({checked} benches within "
          f"{args.tolerance:.0%})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
