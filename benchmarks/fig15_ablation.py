"""Paper Fig. 15: ablation A1-A5 (utilization).

A1 naive FSE-DP · A2 +rules 1-4 micro-slice flow · A3 +paired-load ·
A4 +rule 5 · A5 A3+20% token buffering.
"""
from __future__ import annotations

import numpy as np

from repro.sim import PROTOTYPE_2X2, PAPER_SPECS, iteration_workloads, run_e2e, simulate_layer
from .common import emit

ABLATIONS = [("A1", "fse_dp_naive", 0.0), ("A2", "fse_dp", 0.0),
             ("A3", "fse_dp_paired", 0.0), ("A4", "fse_dp_rule5", 0.0),
             ("A5", "fse_dp_paired", 0.2)]


def run():
    hw = PROTOTYPE_2X2
    rows = []
    for mname in ("phi3.5-moe", "qwen3-a3b"):
        spec = PAPER_SPECS[mname]
        for label, strat, slack in ABLATIONS:
            if slack:
                r = run_e2e(hw, spec, strategy=strat, tokens_per_iter=64,
                            iterations=8, buffering_slack=slack,
                            layer_sample=4, seed=0)
                util, lat = r.mean_utilization, r.total_time / r.iterations
            else:
                utils, lats = [], []
                for seed in range(3):
                    wl = iteration_workloads(spec, tokens_per_iter=64,
                                             num_chiplets=hw.num_chiplets,
                                             seed=seed)[0]
                    res = simulate_layer(hw, spec, wl, strat)
                    utils.append(res.utilization)
                    lats.append(res.latency)
                util, lat = float(np.mean(utils)), float(np.mean(lats))
            rows.append([mname, label, strat, slack, round(util, 4),
                         round(lat * 1e6, 1)])
    emit("fig15_ablation", rows,
         ["model", "ablation", "strategy", "slack", "utilization", "latency_us"])
    return rows


def main():
    run()


if __name__ == "__main__":
    main()
