"""einsum-vs-Pallas sweep for the streamed-MoE expert GEMM.

Benchmarks ``kernels.ops.streamed_moe``'s two branches — the jnp oracle
(``use_kernels(False)``) and the Pallas micro-slice kernel — over the
expert-FFN shapes of the config zoo, at several micro-slice widths
(the quantity that actually streams in FSE-DP's ring), plus the kernel
with tiles chosen by the ``core.autotune`` scheduler
(``ops.streamed_moe_autotuned`` — the same planner every model path
dispatches through), and the int8 quantized-streaming branch
(``weight_dtype="int8"`` — per-channel scales dequantized in VMEM),
recording its deterministic weight-bytes reduction vs bf16 and oracle
parity (gated by check_regression.py; see docs/quantization.md).
Emits ``BENCH_streamed_moe.json`` under artifacts/bench/.

Usage:
  PYTHONPATH=src python benchmarks/kernel_bench.py [--quick] [--full]
      [--tokens N] [--reps N] [--out DIR]

On CPU the Pallas branch runs in interpret mode, so timings there are a
functional smoke of the dispatch layer, not kernel performance; run on
TPU for real numbers (recorded in the JSON's ``interpret`` field).
"""
from __future__ import annotations

import argparse
import json
import os
import statistics
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs.base import get_config, list_configs
from repro.core import autotune
from repro.kernels import ops

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "bench")


def moe_shapes():
    """Deduped (name, E, d_model, d_expert, activation) from the zoo."""
    seen, out = set(), []
    for name in list_configs():
        cfg = get_config(name)
        if cfg.moe is None:
            continue
        key = (cfg.moe.num_experts, cfg.d_model, cfg.moe.d_expert,
               cfg.activation)
        if key in seen:
            continue
        seen.add(key)
        out.append((name,) + key)
    return out


def time_fn(fn, *args, reps):
    jax.block_until_ready(fn(*args))              # compile + warm
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return statistics.median(ts)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small subset / small C (default on CPU)")
    ap.add_argument("--full", action="store_true",
                    help="force the full sweep even on CPU")
    ap.add_argument("--tokens", type=int, default=None,
                    help="capacity rows per expert (C)")
    ap.add_argument("--reps", type=int, default=5)
    ap.add_argument("--out", default=ART)
    args = ap.parse_args(argv)

    backend = jax.default_backend()
    quick = args.quick or (backend == "cpu" and not args.full)
    C = args.tokens or (16 if quick else 128)
    reps = min(args.reps, 2) if quick else args.reps
    budget = (256 if quick else 2048) * 1024 * 1024  # weight bytes per row
    slice_divs = (4, 16) if quick else (1, 4, 16)

    shapes = moe_shapes()
    if quick:
        shapes = shapes[:3]

    rows, skipped = [], 0
    for name, E, d, de, act in shapes:
        for div in slice_divs:
            m = max(1, de // div)
            n_w = 3 if act == "swiglu" else 2
            w_bytes = n_w * E * d * m * 4
            if w_bytes > budget:
                skipped += 1
                continue
            ks = jax.random.split(jax.random.PRNGKey(0), 4)
            xe = jax.random.normal(ks[0], (E, C, d), jnp.float32)
            wu = jax.random.normal(ks[1], (E, d, m), jnp.float32) * 0.1
            wd = jax.random.normal(ks[2], (E, m, d), jnp.float32) * 0.1
            wg = (jax.random.normal(ks[3], (E, d, m), jnp.float32) * 0.1
                  if act == "swiglu" else None)

            def ref_fn(xe, wg, wu, wd):
                with ops.use_kernels(False):
                    return ops.streamed_moe(xe, wg, wu, wd, act)

            def pallas_fn(xe, wg, wu, wd):
                with ops.use_kernels(True):
                    return ops.streamed_moe(xe, wg, wu, wd, act)

            def tuned_fn(xe, wg, wu, wd):
                with ops.use_kernels(True), autotune.use_autotune("analytic"):
                    return ops.streamed_moe_autotuned(xe, wg, wu, wd, act)

            def quant_fn(xe, wg, wu, wd):
                with ops.use_kernels(True):
                    return ops.streamed_moe(xe, wg, wu, wd, act,
                                            weight_dtype="int8")

            t_ref = time_fn(jax.jit(ref_fn), xe, wg, wu, wd, reps=reps)
            t_pal = time_fn(jax.jit(pallas_fn), xe, wg, wu, wd, reps=reps)
            t_tun = time_fn(jax.jit(tuned_fn), xe, wg, wu, wd, reps=reps)
            t_qnt = time_fn(jax.jit(quant_fn), xe, wg, wu, wd, reps=reps)
            tiles = autotune.kernel_opts_for(E, C, d, m, act, dtype_bytes=4,
                                             level="analytic")
            # quantized-streaming accounting + parity (both deterministic,
            # so check_regression gates them machine-independently):
            # int8 weights + per-(expert, output-channel) fp32 scale rows
            # vs the bf16 stream, and the quantized oracle's relative
            # Frobenius distance from the exact fp32 reference
            n_up = 2 if act == "swiglu" else 1
            bf16_bytes = n_w * E * d * m * 2
            int8_bytes = n_w * E * d * m + (n_up * m + d) * E * 4
            y_f = ref_fn(xe, wg, wu, wd)
            with ops.use_kernels(False):
                y_q = ops.streamed_moe(xe, wg, wu, wd, act,
                                       weight_dtype="int8")
            rel = float(jnp.linalg.norm(y_q - y_f) / jnp.linalg.norm(y_f))
            row = {"config": name, "E": E, "d_model": d, "d_expert": de,
                   "slice_div": div, "m_slice": m, "C": C, "activation": act,
                   "einsum_ms": round(t_ref * 1e3, 4),
                   "pallas_ms": round(t_pal * 1e3, 4),
                   "autotuned_ms": round(t_tun * 1e3, 4),
                   "quant_ms": round(t_qnt * 1e3, 4),
                   "quant_weight_bytes": int8_bytes,
                   "bf16_weight_bytes": bf16_bytes,
                   "quant_bytes_reduction": round(1 - int8_bytes / bf16_bytes,
                                                  4),
                   "quant_rel_err": round(rel, 6),
                   "autotuned_tiles": tiles,
                   "speedup": round(t_ref / t_pal, 3) if t_pal else None}
            rows.append(row)
            print(f"{name:24s} E={E:<3d} d={d:<6d} m={m:<6d} C={C:<4d} {act:7s}"
                  f" einsum={row['einsum_ms']:.3f}ms pallas={row['pallas_ms']:.3f}ms"
                  f" tuned={row['autotuned_ms']:.3f}ms x{row['speedup']}"
                  f" int8={row['quant_ms']:.3f}ms"
                  f" (-{row['quant_bytes_reduction']:.0%} bytes,"
                  f" rel {row['quant_rel_err']:.1e})")
    if skipped:
        print(f"# skipped {skipped} rows over the {budget >> 20} MiB "
              f"weight budget (use --full / more RAM)")

    payload = {
        "bench": "streamed_moe_kernel_vs_einsum",
        "backend": backend,
        "interpret": backend == "cpu",
        "jax": jax.__version__,
        "quick": quick,
        "unix_time": int(time.time()),
        "rows": rows,
    }
    os.makedirs(args.out, exist_ok=True)
    path = os.path.join(args.out, "BENCH_streamed_moe.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# {len(rows)} rows -> {os.path.relpath(path)}")
    return path


if __name__ == "__main__":
    main()
