"""Shared benchmark plumbing: CSV emission + timing."""
from __future__ import annotations

import csv
import io
import os
import sys
import time

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "bench")


def emit(name: str, rows: list, header: list) -> None:
    """Print rows as CSV and persist under artifacts/bench/<name>.csv."""
    os.makedirs(ART, exist_ok=True)
    path = os.path.join(ART, f"{name}.csv")
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(header)
        w.writerows(rows)
    print(f"# {name} ({len(rows)} rows) -> {os.path.relpath(path)}")
    w = csv.writer(sys.stdout)
    w.writerow(header)
    for r in rows[:40]:
        w.writerow(r)
    if len(rows) > 40:
        print(f"# ... {len(rows) - 40} more rows in {path}")


class timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.dt = time.time() - self.t0
