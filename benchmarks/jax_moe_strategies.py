"""TPU-adaptation analogue of Fig. 9/12: per-device weight bytes and HLO
collective traffic of DP / TP / EP / FSE-DP MoE layers on a (2,4) mesh
(8 host devices — runs in a subprocess so the parent stays 1-device).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

from .common import emit

_CHILD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import functools
import json
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.configs.base import MoEConfig
from repro.models import moe as moe_mod
from repro.core import autotune, fse_dp, baselines
from repro.parallel import meshctx
from repro.launch.analysis import collective_bytes

E, k, d, de = 16, 2, 256, 512
moe = MoEConfig(num_experts=E, top_k=k, d_expert=de, micro_slices=4)
params = moe_mod.moe_init(jax.random.PRNGKey(0), d, moe, "swiglu", jnp.bfloat16)
mesh = jax.make_mesh((2, 4), ("data", "model"))
B, S = 8, 64
x = jax.random.normal(jax.random.PRNGKey(1), (B, S, d), jnp.bfloat16)

# one scheduler for every strategy: the fse_dp row pins the paper's
# signature stream trajectory via a forced plan; fse_dp_auto lets the
# cost model pick mode/micro-slices/tiles for this shape
B_grp = B // 2                       # data axis is 2-way
stream_plan = autotune.plan_moe(B_grp, S, d, moe, "swiglu", 4,
                                dtype_bytes=2, mode="stream")
fse_dp_stream = functools.partial(fse_dp.fse_dp_moe_3d, plan=stream_plan)

def lower(fn, w_specs):
    in_sh = (jax.tree.map(lambda s: NamedSharding(mesh, s), w_specs),
             NamedSharding(mesh, P("data", "model", None)))
    with meshctx.with_mesh(mesh):
        return jax.jit(lambda p, x: fn(p, x, moe, "swiglu"),
                       in_shardings=in_sh).lower(params_like(w_specs), x).compile()

def params_like(_):
    return params

W = sum(int(v.size) * 2 for kk, v in params.items() if kk.startswith("w_"))
rows = []
specs_fse = {"router": {"w_router": P()}, "w_gate": P(None, None, "model"),
             "w_up": P(None, None, "model"), "w_down": P(None, "model", None)}
specs_ep = {"router": {"w_router": P()}, "w_gate": P("model", None, None),
            "w_up": P("model", None, None), "w_down": P("model", None, None)}
specs_dp = {"router": {"w_router": P()}, "w_gate": P(), "w_up": P(), "w_down": P()}

for name, fn, specs, shard_frac in [
        ("dp_replicated", fse_dp.fse_dp_moe_3d, specs_dp, 1.0),
        ("tp", baselines.tp_moe_3d, specs_fse, 0.25),
        ("ep", baselines.ep_moe_3d, specs_ep, 0.25),
        ("fse_dp", fse_dp_stream, specs_fse, 0.25),
        ("fse_dp_auto", fse_dp.fse_dp_moe_3d, specs_fse, 0.25)]:
    compiled = lower(fn, specs)
    coll = collective_bytes(compiled.as_text())
    rows.append({"strategy": name,
                 "weight_bytes_per_device": int(W * shard_frac),
                 "coll_total": coll["total"],
                 "all_to_all": coll["all-to-all"],
                 "collective_permute": coll["collective-permute"],
                 "all_gather": coll["all-gather"],
                 "all_reduce": coll["all-reduce"] + coll["reduce-scatter"]})
print(json.dumps(rows))
"""


def run():
    env = dict(os.environ, PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _CHILD], env=env,
                         capture_output=True, text=True, timeout=1200)
    if out.returncode != 0:
        raise RuntimeError(f"child failed: {out.stderr[-2000:]}")
    data = json.loads(out.stdout.strip().splitlines()[-1])
    rows = [[r["strategy"], r["weight_bytes_per_device"], int(r["coll_total"]),
             int(r["all_to_all"]), int(r["collective_permute"]),
             int(r["all_gather"]), int(r["all_reduce"])] for r in data]
    emit("jax_moe_strategies", rows,
         ["strategy", "weight_B_per_dev", "coll_total_B", "all_to_all_B",
          "collective_permute_B", "all_gather_B", "all_reduce_B"])
    return rows


def main():
    run()


if __name__ == "__main__":
    main()
