"""TPU-adaptation analogue of Fig. 9/12: per-device weight bytes and HLO
collective traffic of DP / TP / EP / FSE-DP / auto MoE layers on a (2,4)
mesh (8 host devices — runs in a subprocess so the parent stays 1-device).

Every strategy is reached through the execution-strategy registry
(``repro.core.strategy``); the ``auto`` row lets the cross-family
planner pick the winning family for the shape.  A second, host-side
sweep routes Zipf-skewed token loads through the chiplet trajectory
simulation (``sim.modes.schedule_step_times``) and records the static
(shape-only) vs dynamic (gating-count-built paired trajectory) step
time per point — the regression gate requires the dynamic schedule to
keep beating the static plan on a majority of skewed points.  Emits CSVs
plus ``artifacts/bench/BENCH_moe_strategies.json``; the committed copy
under ``benchmarks/baselines/`` is the CI regression baseline
(``check_regression.py``).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

from .common import emit

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "bench")

# (tokens_per_iter, zipf_s, seed) — low-batch decode through prefill-ish
# iteration sizes, at two skew strengths, deterministic routing seeds
SKEW_SWEEP = (
    (16, 1.1, 0), (16, 1.5, 1),
    (32, 1.1, 2), (32, 1.5, 3),
    (128, 1.1, 4), (128, 1.5, 5),
    (512, 1.1, 6), (512, 1.5, 7),
)


def skewed_schedule_rows():
    """Static-vs-dynamic simulated step times on Zipf-routed gating."""
    import numpy as np
    from repro.sim import modes as sim_modes, workload
    from repro.sim.hardware import PROTOTYPE_2X2, ModelSpec

    spec = ModelSpec("skew-bench", 2048, 1408, 64, 6, 3)
    rows = []
    for tokens, zipf_s, seed in SKEW_SWEEP:
        rng = np.random.default_rng(seed)
        p = workload.sample_expert_probs(spec.num_experts, rng, zipf_s)
        counts = workload.route_tokens(spec.num_experts, spec.top_k,
                                       tokens, p, rng)
        t = sim_modes.schedule_step_times(PROTOTYPE_2X2, spec, counts)
        rows.append({"tokens": tokens, "zipf_s": zipf_s, "seed": seed,
                     "active_experts": int((counts > 0).sum()),
                     "static_us": t["static"] * 1e6,
                     "dynamic_us": t["dynamic"] * 1e6,
                     "dynamic_unpaired_us": t["dynamic_unpaired"] * 1e6,
                     "win": bool(t["dynamic"] < t["static"])})
    return rows

# (E, d_expert, tokens, zipf_s) — compute-sensitive Zipf points where
# the load-aware fast-tier partition must beat the static id-prefix
DYN_PARTITION_SWEEP = (
    (64, 1408, 256, 1.2), (64, 1408, 512, 1.2),
    (64, 768, 512, 1.4), (32, 1408, 256, 1.2),
)


def hybrid_sweep_rows():
    """Two-tier hybrid referee sweep (host-side, deterministic).

    For every point of the committed ``strategy.HYBRID_SWEEP`` the
    analytic family cost model picks a winner and the chiplet simulator
    referees it; the regression gate requires >=80% agreement and each
    of hybrid / EP / FSE-DP winning somewhere.  A second block prices
    the dynamic (EMA-hottest) fast-tier partition against the static
    top-N id prefix on Zipf-skewed load.
    """
    import numpy as np
    from repro.configs.base import MoEConfig
    from repro.core import autotune
    from repro.core import strategy as strat
    from repro.sim import hardware as hwmod
    from repro.sim import modes as sim_modes, workload

    def ndp_hw(P):
        base = {2: hwmod.scaled(1, 2), 4: hwmod.scaled(2, 2),
                8: hwmod.scaled(2, 4)}[P]
        return hwmod.with_ndp(base)

    sweep = []
    for (B, S, E, de, P, zs) in strat.HYBRID_SWEEP:
        hw = ndp_hw(P)
        profile = autotune.HardwareProfile.from_chiplet(hw)
        moe = MoEConfig(num_experts=E, top_k=2, d_expert=de)
        loads = None
        if zs > 0:
            rng = np.random.default_rng(0)
            loads = workload.sample_expert_probs(E, rng, zipf_s=zs)
        lt = None if loads is None else tuple(float(v) for v in loads)
        costs = strat.family_costs(B, S, 512, moe, "swiglu", P,
                                   profile=profile, load=lt)
        chosen = strat.pick_family(costs)
        sim = sim_modes.rank_families(
            hw, hwmod.ModelSpec("s", 512, de, E, 2), B * S, B=B, S=S,
            loads=loads)
        best = min((f for f in strat.FAMILIES if f in sim),
                   key=lambda f: sim[f])
        sweep.append({"B": B, "S": S, "E": E, "d_expert": de, "P": P,
                      "zipf_s": zs, "cost_family": chosen,
                      "sim_family": best, "sim_us": sim[best] * 1e6,
                      "agree": bool(chosen == best)})

    hw = ndp_hw(4)
    partition = []
    for (E, de, tokens, zs) in DYN_PARTITION_SWEEP:
        spec = hwmod.ModelSpec("s", 512, de, E, 2)
        rng = np.random.default_rng(7)
        loads = workload.sample_expert_probs(E, rng, zipf_s=zs)
        N = strat.default_hot(E)
        static = sim_modes.simulate_hybrid(
            hw, spec, tokens, loads=loads, hot_ids=range(N)).latency
        dyn_ids = np.argsort(-loads, kind="stable")[:N]
        dynamic = sim_modes.simulate_hybrid(
            hw, spec, tokens, loads=loads, hot_ids=dyn_ids).latency
        partition.append({"E": E, "d_expert": de, "tokens": tokens,
                          "zipf_s": zs, "hot_n": N,
                          "static_us": static * 1e6,
                          "dynamic_us": dynamic * 1e6,
                          "win": bool(dynamic < static)})
    return {"sweep": sweep, "partition": partition}


_CHILD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.configs.base import MoEConfig
from repro.models import moe as moe_mod
from repro.core import autotune, strategy
from repro.parallel import meshctx
from repro.launch.analysis import collective_bytes

E, k, d, de = 16, 2, 256, 512
moe = MoEConfig(num_experts=E, top_k=k, d_expert=de, micro_slices=4)
params = moe_mod.moe_init(jax.random.PRNGKey(0), d, moe, "swiglu", jnp.bfloat16)
mesh = jax.make_mesh((2, 4), ("data", "model"))
B, S = 8, 64
x = jax.random.normal(jax.random.PRNGKey(1), (B, S, d), jnp.bfloat16)

# one registry for every strategy: the fse_dp row pins the paper's
# signature stream trajectory via a forced plan; fse_dp_auto lets the
# within-family cost model pick mode/micro-slices/tiles; auto lets the
# cross-family planner pick the winning *family* for this shape
B_grp = B // 2                       # data axis is 2-way
stream_plan = autotune.plan_moe(B_grp, S, d, moe, "swiglu", 4,
                                dtype_bytes=2, mode="stream")
family_plan = strategy.plan_family(B_grp, S, d, moe, "swiglu", 4,
                                   dtype_bytes=2)

def run(name, plan=None):
    def fn(p, x, moe, act):
        return strategy.execute(name, p, x, moe, act, plan=plan)
    return fn

def lower(fn, w_specs):
    in_sh = (jax.tree.map(lambda s: NamedSharding(mesh, s), w_specs),
             NamedSharding(mesh, P("data", "model", None)))
    with meshctx.with_mesh(mesh):
        return jax.jit(lambda p, x: fn(p, x, moe, "swiglu"),
                       in_shardings=in_sh).lower(params_like(w_specs), x).compile()

def params_like(_):
    return params

W = sum(int(v.size) * 2 for kk, v in params.items() if kk.startswith("w_"))
rows = []
specs_fse = {"router": {"w_router": P()}, "w_gate": P(None, None, "model"),
             "w_up": P(None, None, "model"), "w_down": P(None, "model", None)}
specs_ep = {"router": {"w_router": P()}, "w_gate": P("model", None, None),
            "w_up": P("model", None, None), "w_down": P("model", None, None)}
specs_dp = {"router": {"w_router": P()}, "w_gate": P(), "w_up": P(), "w_down": P()}

auto_specs = {"fse_dp": specs_fse, "tp": specs_fse, "ep": specs_ep}
for name, fn, specs, shard_frac in [
        ("dp_replicated", run("fse_dp"), specs_dp, 1.0),
        ("tp", run("tp"), specs_fse, 0.25),
        ("ep", run("ep"), specs_ep, 0.25),
        ("fse_dp", run("fse_dp", stream_plan), specs_fse, 0.25),
        ("fse_dp_auto", run("fse_dp"), specs_fse, 0.25),
        ("auto", run("auto"), auto_specs[family_plan.family], 0.25)]:
    compiled = lower(fn, specs)
    coll = collective_bytes(compiled.as_text())
    rows.append({"strategy": name,
                 "weight_bytes_per_device": int(W * shard_frac),
                 "coll_total": coll["total"],
                 "all_to_all": coll["all-to-all"],
                 "collective_permute": coll["collective-permute"],
                 "all_gather": coll["all-gather"],
                 "all_reduce": coll["all-reduce"] + coll["reduce-scatter"]})
print(json.dumps({"rows": rows, "auto_family": family_plan.family,
                  "shape": {"B": B, "S": S, "E": E, "d_model": d,
                            "d_expert": de, "mesh": "2x4"}}))
"""


def run():
    env = dict(os.environ, PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _CHILD], env=env,
                         capture_output=True, text=True, timeout=1200)
    if out.returncode != 0:
        raise RuntimeError(f"child failed: {out.stderr[-2000:]}")
    data = json.loads(out.stdout.strip().splitlines()[-1])
    rows = [[r["strategy"], r["weight_bytes_per_device"], int(r["coll_total"]),
             int(r["all_to_all"]), int(r["collective_permute"]),
             int(r["all_gather"]), int(r["all_reduce"])] for r in data["rows"]]
    emit("jax_moe_strategies", rows,
         ["strategy", "weight_B_per_dev", "coll_total_B", "all_to_all_B",
          "collective_permute_B", "all_gather_B", "all_reduce_B"])

    skewed = skewed_schedule_rows()
    emit("jax_moe_strategies_skewed",
         [[r["tokens"], r["zipf_s"], r["active_experts"],
           round(r["static_us"], 2), round(r["dynamic_us"], 2),
           int(r["win"])] for r in skewed],
         ["tokens", "zipf_s", "active_E", "static_us", "dynamic_us", "win"])
    wins = sum(r["win"] for r in skewed)
    print(f"# skewed gating: dynamic schedule wins {wins}/{len(skewed)} "
          f"points")

    hybrid = hybrid_sweep_rows()
    emit("jax_moe_strategies_hybrid",
         [[r["B"], r["S"], r["E"], r["d_expert"], r["P"], r["zipf_s"],
           r["cost_family"], r["sim_family"], int(r["agree"])]
          for r in hybrid["sweep"]],
         ["B", "S", "E", "d_expert", "P", "zipf_s", "cost_family",
          "sim_family", "agree"])
    n_agree = sum(r["agree"] for r in hybrid["sweep"])
    part_wins = sum(r["win"] for r in hybrid["partition"])
    print(f"# hybrid two-tier: cost/sim agreement "
          f"{n_agree}/{len(hybrid['sweep'])}, dynamic partition wins "
          f"{part_wins}/{len(hybrid['partition'])}")

    import jax
    payload = {
        "bench": "jax_moe_strategies",
        "backend": jax.default_backend(),
        "jax": jax.__version__,
        "unix_time": int(time.time()),
        "auto_family": data["auto_family"],
        "shape": data["shape"],
        "rows": data["rows"],
        "skewed": skewed,
        "hybrid": hybrid,
    }
    os.makedirs(ART, exist_ok=True)
    path = os.path.join(ART, "BENCH_moe_strategies.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# auto family: {data['auto_family']} -> {os.path.relpath(path)}")
    return rows


def main():
    run()


if __name__ == "__main__":
    main()
