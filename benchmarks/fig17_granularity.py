"""Paper Fig. 17: micro-slice granularity × on-chip expert storage
latency heatmap (Phi-3.5 and Qwen3-A3B)."""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.sim import PAPER_SPECS, PROTOTYPE_2X2, iteration_workloads, simulate_layer
from .common import emit


def run():
    rows = []
    for mname in ("phi3.5-moe", "qwen3-a3b"):
        spec = PAPER_SPECS[mname]
        for buf_mb in (4, 8, 16, 32):
            for micro in (1, 2, 4, 8, 16):
                hw = dataclasses.replace(PROTOTYPE_2X2,
                                         buffer_bytes=buf_mb * 2 ** 20)
                lats = []
                for seed in (0, 1):
                    wl = iteration_workloads(spec, tokens_per_iter=64,
                                             num_chiplets=hw.num_chiplets,
                                             seed=seed)[0]
                    lats.append(simulate_layer(hw, spec, wl, "fse_dp_paired",
                                               micro_slices=micro).latency)
                rows.append([mname, buf_mb, micro,
                             round(float(np.mean(lats)) * 1e6, 1)])
    emit("fig17_granularity", rows,
         ["model", "buffer_MB", "micro_slices_per_chiplet_slice", "latency_us"])
    return rows


def main():
    run()


if __name__ == "__main__":
    main()
