"""Paper Fig. 12: on-chip memory usage per strategy per model (peak
package bytes while achieving the Fig. 9 latencies)."""
from __future__ import annotations

import numpy as np

from repro.sim import PROTOTYPE_2X2, PAPER_SPECS, iteration_workloads, simulate_layer
from .common import emit

STRATS = ("ep", "hydra", "fse_dp_naive", "fse_dp", "fse_dp_paired")


def run():
    hw = PROTOTYPE_2X2
    rows = []
    for mname, spec in PAPER_SPECS.items():
        wl = iteration_workloads(spec, tokens_per_iter=64,
                                 num_chiplets=hw.num_chiplets, seed=0)[0]
        mems = {}
        for s in STRATS:
            r = simulate_layer(hw, spec, wl, s)
            mems[s] = r.peak_buffer_bytes
        for s in STRATS:
            saving = 1.0 - mems[s] / max(mems["ep"], 1)
            rows.append([mname, s, round(mems[s] / 2 ** 20, 1),
                         round(100 * saving, 1)])
    emit("fig12_memory", rows,
         ["model", "strategy", "peak_package_MB", "saving_vs_ep_pct"])
    return rows


def main():
    run()


if __name__ == "__main__":
    main()
