"""Paper Fig. 14: end-to-end throughput (attention + all MoE layers,
multiple forward iterations) with token-buffering slack 0/10/20/30%."""
from __future__ import annotations

from repro.sim import PROTOTYPE_2X2, PAPER_SPECS, run_e2e
from .common import emit

CONFIGS = [("ep", 0.0), ("hydra", 0.0), ("fse_dp_paired", 0.0),
           ("fse_dp_paired", 0.1), ("fse_dp_paired", 0.2),
           ("fse_dp_paired", 0.3)]


def run(iterations: int = 12, layer_sample: int = 6):
    hw = PROTOTYPE_2X2
    rows = []
    for mname, spec in PAPER_SPECS.items():
        base = None
        for strat, slack in CONFIGS:
            r = run_e2e(hw, spec, strategy=strat, tokens_per_iter=64,
                        iterations=iterations, buffering_slack=slack,
                        layer_sample=layer_sample, seed=0)
            if base is None:
                base = r.throughput
            rows.append([mname, strat, slack, round(r.throughput, 2),
                         round(r.throughput / base, 3), r.deferral_events,
                         round(r.mean_utilization, 4)])
    emit("fig14_e2e_throughput", rows,
         ["model", "strategy", "slack", "tokens_per_s", "speedup_vs_ep",
          "deferrals", "mean_util"])
    return rows


def main():
    run()


if __name__ == "__main__":
    main()
