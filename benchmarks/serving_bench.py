"""Serving-latency benchmark: closed-loop Poisson traffic through the
continuous-batching scheduler (chunked prefill + Algorithm-2 engine).

Emits ``artifacts/bench/BENCH_serving.json`` with two metric classes:

* **deterministic** (gated by ``check_regression.py`` against the
  committed baseline): iteration-clocked TTFT / TPOT / queue-delay
  percentiles, completed/emitted counts, engine iterations and prefill
  chunks.  The scheduler runs on the iteration clock (each step
  advances the metric clock by 1), so these are bit-reproducible across
  machines — a drift means the scheduler or engine genuinely changed.
* **informational** wall-clock timings (tok/s) — recorded, not gated.

Usage:  PYTHONPATH=src python benchmarks/serving_bench.py [--quick]
"""
from __future__ import annotations

import argparse
import json
import os
import time

from common import ART


def run(quick: bool = False) -> dict:
    import jax
    from repro.configs import reduced_config
    from repro.models import api
    from repro.serving import (Engine, ServeConfig, Scheduler,
                               SchedulerConfig, TrafficConfig, make_traffic,
                               run_closed_loop)

    cfg = reduced_config("granite-moe-1b-a400m").replace(dtype="float32")
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    n_req = 8 if quick else 16
    tcfg = TrafficConfig(num_requests=n_req, rate=0.8, avg_prompt=10,
                         max_prompt=24, min_new=2, max_new=5,
                         vocab=cfg.vocab_size, seed=0)
    traffic = make_traffic(tcfg)
    eng = Engine(params, cfg, ServeConfig(max_batch=4, max_ctx=32,
                                          chunk_tokens=4, spec="capacity"))
    sched = Scheduler(eng, SchedulerConfig(queue_capacity=64, policy="fcfs"))
    t0 = time.time()
    res = run_closed_loop(sched, traffic)
    wall_s = time.time() - t0
    m = res["metrics"]
    out = {
        "workload": {"requests": n_req, "rate": tcfg.rate,
                     "avg_prompt": tcfg.avg_prompt, "chunk_tokens": 4,
                     "max_batch": 4, "seed": tcfg.seed},
        # deterministic, iteration-clocked — gated against the baseline
        "ttft_iters": m.ttft, "tpot_iters": m.tpot,
        "queue_delay_iters": m.queue_delay,
        "completed": m.completed, "dropped": len(res["dropped"]),
        "tokens_emitted": m.tokens_emitted, "iterations": m.iterations,
        "prefill_chunks": eng.stats["prefill_chunks"],
        "prefill_tokens": eng.stats["prefill_tokens"],
        # informational wall-clock (machine-dependent, not gated)
        "wall_s": wall_s,
        "throughput_tok_s": m.tokens_emitted / max(wall_s, 1e-9),
    }
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller request count (CI)")
    args = ap.parse_args()
    out = run(quick=args.quick)
    os.makedirs(ART, exist_ok=True)
    path = os.path.join(ART, "BENCH_serving.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)
    print(json.dumps(out, indent=2, sort_keys=True))
    print(f"-> {os.path.relpath(path)}")


if __name__ == "__main__":
    main()
