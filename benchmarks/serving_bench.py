"""Serving-latency benchmark: closed-loop Poisson traffic through the
continuous-batching scheduler (chunked prefill + Algorithm-2 engine,
fused mega-step iteration).

Emits ``artifacts/bench/BENCH_serving.json`` with three metric classes
(see docs/benchmarks.md for the full schema):

* **deterministic, iteration-clocked** (gated by ``check_regression.py``
  against the committed baseline): TTFT / TPOT / queue-delay
  percentiles, completed/emitted counts, engine iterations and prefill
  chunks.  The scheduler runs on the iteration clock (each step
  advances the metric clock by 1), so these are bit-reproducible across
  machines — a drift means the scheduler or engine genuinely changed.
* **deterministic, modeled seconds** (gated): the same latency
  percentiles on the engine's closed-form chiplet-array clock
  (``autotune.ServingCostModel`` — Table-I constants, so still
  machine-independent), plus the agreement ratio against the
  ``sim.modes.replay_trace`` event referee (must stay within
  ``MODEL_REFEREE_TOL``).
* **informational wall clock** — machine-dependent; recorded so a human
  can eyeball a local slowdown, never gated and never a baseline.

A second closed loop runs the ``zipf_prefix`` traffic mix (Zipf-shared
system prompts) twice — prefix caching off, then on — and gates the
**prefix_mix** block: cached-run outputs must match the uncached run
token-for-token, and the prefill-compute savings fraction must stay
over the 40% floor and never regress against the baseline.  Pool
accounting (peak pages, resident bytes) rides along informationally.

Usage:  PYTHONPATH=src python benchmarks/serving_bench.py [--quick]
"""
from __future__ import annotations

import argparse
import json
import os
import time

from common import ART

# model-vs-referee agreement band for the aggregate modeled seconds
# (measured headroom: the ratio sits within 0.5% on this workload)
MODEL_REFEREE_TOL = 0.05


def run(quick: bool = False) -> dict:
    import jax
    from repro.configs import reduced_config
    from repro.models import api
    from repro.serving import (Engine, ServeConfig, Scheduler,
                               SchedulerConfig, TrafficConfig, make_traffic,
                               run_closed_loop)
    from repro.sim.hardware import PROTOTYPE_2X2, spec_from_config
    from repro.sim.modes import replay_trace

    cfg = reduced_config("granite-moe-1b-a400m").replace(dtype="float32")
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    n_req = 8 if quick else 16
    tcfg = TrafficConfig(num_requests=n_req, rate=0.8, avg_prompt=10,
                         max_prompt=24, min_new=2, max_new=5,
                         vocab=cfg.vocab_size, seed=0)
    traffic = make_traffic(tcfg)
    eng = Engine(params, cfg, ServeConfig(max_batch=4, max_ctx=32,
                                          chunk_tokens=4, spec="capacity"))
    sched = Scheduler(eng, SchedulerConfig(queue_capacity=64, policy="fcfs"))
    t0 = time.time()
    res = run_closed_loop(sched, traffic)
    wall_s = time.time() - t0
    m = res["metrics"]

    # modeled-vs-referee agreement: the engine's closed-form per-record
    # clock replayed against the discrete expert-flow event loop
    model_total_s = sum(rec.get("modeled_s", 0.0) for rec in eng.trace)
    referee_total_s = replay_trace(
        PROTOTYPE_2X2, spec_from_config(eng.cfg), eng.trace,
        capacity_factor=eng.cfg.moe.capacity_factor)
    out = {
        "workload": {"requests": n_req, "rate": tcfg.rate,
                     "avg_prompt": tcfg.avg_prompt, "chunk_tokens": 4,
                     "max_batch": 4, "seed": tcfg.seed},
        # deterministic, iteration-clocked — gated against the baseline
        "ttft_iters": m.ttft, "tpot_iters": m.tpot,
        "queue_delay_iters": m.queue_delay,
        "completed": m.completed, "dropped": len(res["dropped"]),
        "tokens_emitted": m.tokens_emitted, "iterations": m.iterations,
        "prefill_chunks": eng.stats["prefill_chunks"],
        "prefill_tokens": eng.stats["prefill_tokens"],
        # deterministic modeled chiplet-array seconds — gated
        "modeled": {
            "ttft_s": m.ttft_modeled, "tpot_s": m.tpot_modeled,
            "queue_delay_s": m.queue_delay_modeled,
            "elapsed_s": m.elapsed_modeled,
            "throughput_tok_s": m.throughput_modeled,
            "model_total_s": model_total_s,
            "referee_total_s": referee_total_s,
            "referee_ratio": model_total_s / max(referee_total_s, 1e-30),
            "profile": eng.cost_model.profile.name,
        },
        # machine-dependent wall clock — recorded, never gated
        "wall_clock_informational": {
            "note": "host wall seconds; machine-dependent, not gated",
            "wall_s": wall_s,
            "throughput_tok_s": m.tokens_emitted / max(wall_s, 1e-9),
        },
        # state-pool accounting for the Poisson run — informational
        "state_pool_informational": {
            "note": "paged-pool footprint; shapes may change, not gated",
            "pool_pages": eng.stats["pool_pages"],
            "peak_pages": eng.stats["pool_peak_pages"],
            "peak_resident_state_bytes":
                eng.stats["peak_resident_state_bytes"],
        },
    }

    # ------------------------------------------------------------------
    # shared-prefix mix: prefix caching off vs on (gated)
    # ------------------------------------------------------------------
    pcfg = TrafficConfig(num_requests=n_req, rate=0.8, avg_prompt=10,
                         max_prompt=24, min_new=2, max_new=5,
                         vocab=cfg.vocab_size, seed=0,
                         mix="poisson+zipf_prefix", num_prefixes=2,
                         prefix_len=12)
    ptraffic = make_traffic(pcfg)

    def prefix_run(prefix_cache: bool):
        e = Engine(params, cfg, ServeConfig(
            max_batch=4, max_ctx=32, chunk_tokens=4, spec="capacity",
            prefix_cache=prefix_cache))
        s = Scheduler(e, SchedulerConfig(queue_capacity=64, policy="fcfs"))
        r = run_closed_loop(s, ptraffic)
        return e, r

    eng_off, res_off = prefix_run(False)
    eng_on, res_on = prefix_run(True)
    base_tokens = eng_off.stats["prefill_tokens"]
    out["prefix_mix"] = {
        "workload": {"requests": n_req, "mix": pcfg.mix,
                     "num_prefixes": pcfg.num_prefixes,
                     "prefix_len": pcfg.prefix_len, "seed": pcfg.seed},
        "prefill_tokens_off": base_tokens,
        "prefill_tokens_on": eng_on.stats["prefill_tokens"],
        "savings_frac": (base_tokens - eng_on.stats["prefill_tokens"])
        / max(base_tokens, 1),
        "cache_hits": eng_on.stats["cache_hits"],
        "cache_misses": eng_on.stats["cache_misses"],
        "cache_hit_rate": eng_on.stats["cache_hits"]
        / max(eng_on.stats["cache_hits"] + eng_on.stats["cache_misses"], 1),
        "tokens_emitted": res_on["metrics"].tokens_emitted,
        # bit-identity: cached admission must not change a single token
        "outputs_match_pool_off": res_on["outputs"] == res_off["outputs"],
    }
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller request count (CI)")
    args = ap.parse_args()
    out = run(quick=args.quick)
    os.makedirs(ART, exist_ok=True)
    path = os.path.join(ART, "BENCH_serving.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)
    print(json.dumps(out, indent=2, sort_keys=True))
    print(f"-> {os.path.relpath(path)}")


if __name__ == "__main__":
    main()
