"""Paper Fig. 16: design-space exploration.

(a) buffer size × DDR bandwidth at fixed 288 GB/s D2D
(b) DDR bandwidth × D2D bandwidth at fixed 14 MB buffer
Reports utilization for Qwen3-A3B @ 64 input tokens (paper setup).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.sim import PAPER_SPECS, PROTOTYPE_2X2, iteration_workloads, simulate_layer
from .common import emit

SPEC = PAPER_SPECS["qwen3-a3b"]


def _util(hw, seeds=(0, 1)):
    us = []
    for seed in seeds:
        wl = iteration_workloads(SPEC, tokens_per_iter=64,
                                 num_chiplets=hw.num_chiplets, seed=seed)[0]
        us.append(simulate_layer(hw, SPEC, wl, "fse_dp_paired").utilization)
    return float(np.mean(us))


def run():
    rows = []
    # (a) buffer MB x DDR GB/s per channel (4 channels)
    for buf_mb in (2, 4, 8, 16, 32):
        for ddr in (6.4, 12.8, 25.6, 51.2):
            hw = dataclasses.replace(PROTOTYPE_2X2,
                                     buffer_bytes=buf_mb * 2 ** 20,
                                     ddr_gbps_per_channel=ddr * 1e9)
            rows.append(["a_buffer_x_ddr", buf_mb, ddr * 4, 288,
                         round(_util(hw), 4)])
    # (b) DDR x D2D at 14MB buffer
    for ddr in (6.4, 12.8, 25.6, 51.2):
        for d2d in (72, 144, 288, 512):
            hw = dataclasses.replace(PROTOTYPE_2X2,
                                     buffer_bytes=14 * 2 ** 20,
                                     ddr_gbps_per_channel=ddr * 1e9,
                                     d2d_gbps=d2d * 1e9)
            rows.append(["b_ddr_x_d2d", 14, ddr * 4, d2d, round(_util(hw), 4)])
    emit("fig16_dse", rows,
         ["sweep", "buffer_MB", "ddr_total_GBps", "d2d_GBps", "utilization"])
    return rows


def main():
    run()


if __name__ == "__main__":
    main()
