"""Per-kernel allclose vs ref.py oracles — shape/dtype sweeps + hypothesis."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_kernel
from repro.kernels.ssd import ssd_intra_chunk_kernel
from repro.kernels.streamed_moe import streamed_moe_kernel


# ---------------------------------------------------------------------------
# streamed_moe
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("E,C,d,m", [(2, 8, 16, 8), (4, 100, 64, 24),
                                     (8, 128, 128, 32), (1, 1, 8, 8)])
@pytest.mark.parametrize("act", ["swiglu", "relu2", "gelu"])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_streamed_moe(E, C, d, m, act, dtype):
    ks = jax.random.split(jax.random.PRNGKey(E * 10 + C), 4)
    xe = jax.random.normal(ks[0], (E, C, d), dtype)
    wg = (jax.random.normal(ks[1], (E, d, m), jnp.float32) * 0.1).astype(dtype)
    wu = (jax.random.normal(ks[2], (E, d, m), jnp.float32) * 0.1).astype(dtype)
    wd = (jax.random.normal(ks[3], (E, m, d), jnp.float32) * 0.1).astype(dtype)
    got = streamed_moe_kernel(xe, wg, wu, wd, activation=act, token_tile=32)
    want = ref.streamed_moe_ref(xe, wg, wu, wd, act)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=tol, atol=tol)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 5), st.integers(1, 50), st.sampled_from([16, 32]),
       st.sampled_from([8, 16]))
def test_streamed_moe_property(E, C, d, m):
    ks = jax.random.split(jax.random.PRNGKey(E * 1000 + C), 4)
    xe = jax.random.normal(ks[0], (E, C, d), jnp.float32)
    wg = jax.random.normal(ks[1], (E, d, m), jnp.float32) * 0.1
    wu = jax.random.normal(ks[2], (E, d, m), jnp.float32) * 0.1
    wd = jax.random.normal(ks[3], (E, m, d), jnp.float32) * 0.1
    got = streamed_moe_kernel(xe, wg, wu, wd, activation="swiglu", token_tile=16)
    want = ref.streamed_moe_ref(xe, wg, wu, wd, "swiglu")
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


def test_streamed_moe_slice_sum_equals_full():
    """Σ over d_expert micro-slices == whole-expert FFN — the FSE-DP
    order-invariance (virtualization) property at kernel level."""
    E, C, d, de, M = 2, 16, 32, 64, 4
    ks = jax.random.split(jax.random.PRNGKey(7), 4)
    xe = jax.random.normal(ks[0], (E, C, d), jnp.float32)
    wg = jax.random.normal(ks[1], (E, d, de), jnp.float32) * 0.1
    wu = jax.random.normal(ks[2], (E, d, de), jnp.float32) * 0.1
    wd = jax.random.normal(ks[3], (E, de, d), jnp.float32) * 0.1
    full = ref.streamed_moe_ref(xe, wg, wu, wd, "swiglu")
    mic = de // M
    parts = [streamed_moe_kernel(xe, wg[..., i*mic:(i+1)*mic],
                                 wu[..., i*mic:(i+1)*mic],
                                 wd[:, i*mic:(i+1)*mic, :], activation="swiglu")
             for i in np.random.permutation(M)]          # any order
    np.testing.assert_allclose(sum(parts), full, rtol=3e-5, atol=3e-5)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,hd", [(1, 64, 2, 16), (2, 100, 4, 32),
                                      (1, 256, 1, 64), (1, 17, 2, 8)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(B, S, H, hd, dtype):
    ks = jax.random.split(jax.random.PRNGKey(B * 100 + S), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, H, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, H, hd), dtype)
    got = flash_attention_kernel(q, k, v, q_tile=32, k_tile=32)
    want = ref.flash_attention_ref(q, k, v)
    tol = 2e-4 if dtype == jnp.float32 else 4e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=tol, atol=tol)


def test_flash_rectangular_kv():
    """Sk > Sq (cached prefix) aligns causality to the right edge."""
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    q = jax.random.normal(ks[0], (1, 32, 2, 16), jnp.float32)
    k = jax.random.normal(ks[1], (1, 64, 2, 16), jnp.float32)
    v = jax.random.normal(ks[2], (1, 64, 2, 16), jnp.float32)
    got = flash_attention_kernel(q, k, v, q_tile=16, k_tile=16)
    want = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# SSD intra-chunk
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,nc,c,h,p,n", [(1, 2, 16, 2, 8, 4),
                                          (2, 3, 32, 4, 16, 8),
                                          (1, 1, 8, 1, 4, 4)])
def test_ssd_intra_chunk(b, nc, c, h, p, n):
    ks = jax.random.split(jax.random.PRNGKey(b * 10 + nc), 4)
    xc = jax.random.normal(ks[0], (b, nc, c, h, p), jnp.float32)
    Bc = jax.random.normal(ks[1], (b, nc, c, h, n), jnp.float32)
    Cc = jax.random.normal(ks[2], (b, nc, c, h, n), jnp.float32)
    Ac = -jnp.abs(jax.random.normal(ks[3], (b, h, nc, c), jnp.float32)) * 0.1
    Acum = jnp.cumsum(Ac, -1)
    gy, gs = ssd_intra_chunk_kernel(xc, Bc, Cc, Ac, Acum)
    wy, ws = ref.ssd_intra_chunk_ref(xc, Bc, Cc, Ac, Acum)
    np.testing.assert_allclose(gy, wy, rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(gs, ws, rtol=2e-5, atol=2e-5)
