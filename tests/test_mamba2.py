"""Mamba-2 SSD: chunked == naive recurrence == kernel path; decode chain."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SSMConfig
from repro.models import mamba2 as m2


def _rand_inputs(key, b, l, h, p, n, g=1):
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, l, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h), jnp.float32))
    A = -jnp.abs(jax.random.normal(ks[2], (h,), jnp.float32))
    Bm = jax.random.normal(ks[3], (b, l, g, n), jnp.float32)
    Cm = jax.random.normal(ks[4], (b, l, g, n), jnp.float32)
    return x, dt, A, Bm, Cm


@pytest.mark.parametrize("chunk,l", [(8, 32), (16, 64), (8, 128)])
def test_chunked_matches_naive(chunk, l):
    x, dt, A, Bm, Cm = _rand_inputs(jax.random.PRNGKey(0), 2, l, 4, 8, 4)
    y1, s1 = m2.ssd_chunked(x, dt, A, Bm, Cm, chunk)
    y2, s2 = m2.ssd_naive(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(y1, y2, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(s1, s2, rtol=2e-4, atol=2e-4)


def test_kernel_path_matches():
    x, dt, A, Bm, Cm = _rand_inputs(jax.random.PRNGKey(1), 1, 64, 2, 16, 8)
    y1, s1 = m2.ssd_chunked(x, dt, A, Bm, Cm, 16, use_kernel=True)
    y2, s2 = m2.ssd_chunked(x, dt, A, Bm, Cm, 16, use_kernel=False)
    np.testing.assert_allclose(y1, y2, rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(s1, s2, rtol=2e-5, atol=2e-5)


def test_scan_path_matches():
    """nc >= 16 triggers the lax.map long-sequence path."""
    x, dt, A, Bm, Cm = _rand_inputs(jax.random.PRNGKey(2), 1, 16 * 8, 2, 8, 4)
    y1, s1 = m2.ssd_chunked(x, dt, A, Bm, Cm, 8)
    y2, s2 = m2.ssd_naive(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(y1, y2, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(s1, s2, rtol=2e-4, atol=2e-4)


def test_initial_state_carries():
    x, dt, A, Bm, Cm = _rand_inputs(jax.random.PRNGKey(3), 1, 32, 2, 4, 4)
    y_full, s_full = m2.ssd_chunked(x, dt, A, Bm, Cm, 8)
    y1, s1 = m2.ssd_chunked(x[:, :16], dt[:, :16], A, Bm[:, :16], Cm[:, :16], 8)
    y2, s2 = m2.ssd_chunked(x[:, 16:], dt[:, 16:], A, Bm[:, 16:], Cm[:, 16:], 8,
                            initial_state=s1)
    np.testing.assert_allclose(jnp.concatenate([y1, y2], 1), y_full,
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(s2, s_full, rtol=2e-4, atol=2e-4)


def test_block_decode_matches_prefill():
    """Running the block token-by-token == full-sequence forward."""
    ssm = SSMConfig(d_state=8, d_conv=4, expand=2, head_dim=8, chunk_size=8)
    d = 16
    params = m2.mamba2_init(jax.random.PRNGKey(4), d, ssm, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 16, d), jnp.float32)
    y_full = m2.mamba2_block(params, x, ssm, d)

    y_pre, state = m2.mamba2_prefill(params, x[:, :8], ssm, d)
    np.testing.assert_allclose(y_pre, y_full[:, :8], rtol=2e-3, atol=2e-3)
    ys = []
    for t in range(8, 16):
        y_t, state = m2.mamba2_decode(params, x[:, t:t + 1], state, ssm, d)
        ys.append(y_t)
    y_dec = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(y_dec, y_full[:, 8:], rtol=2e-3, atol=2e-3)
