"""Attention: causality, GQA, decode==full, chunked==dense, flash==ref."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import attention as at

D, H, KV, HD = 32, 4, 2, 8


@pytest.fixture(scope="module")
def params():
    return at.attn_init(jax.random.PRNGKey(0), D, H, KV, HD, jnp.float32)


def test_causality(params):
    """Changing a future token never changes an earlier output."""
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 10, D))
    y1 = at.attention(params, x, n_heads=H, n_kv=KV, head_dim=HD, rope_theta=1e4)
    x2 = x.at[0, 7].set(99.0)
    y2 = at.attention(params, x2, n_heads=H, n_kv=KV, head_dim=HD, rope_theta=1e4)
    np.testing.assert_allclose(y1[0, :7], y2[0, :7], atol=1e-5)
    assert not np.allclose(y1[0, 8:], y2[0, 8:], atol=1e-5)


def test_gqa_equals_mha_when_kv_repeated():
    """GQA(kv=2) == MHA with repeated kv weights."""
    p = at.attn_init(jax.random.PRNGKey(2), D, H, KV, HD, jnp.float32)
    p_full = dict(p)
    p_full["wk"] = jnp.concatenate([p["wk"].reshape(D, KV, HD)] * (H // KV),
                                   axis=1).reshape(D, H * HD)
    # interleave must match _repeat_kv (jnp.repeat): build accordingly
    wk = p["wk"].reshape(D, KV, HD)
    p_full["wk"] = jnp.repeat(wk, H // KV, axis=1).reshape(D, H * HD)
    wv = p["wv"].reshape(D, KV, HD)
    p_full["wv"] = jnp.repeat(wv, H // KV, axis=1).reshape(D, H * HD)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 6, D))
    y_g = at.attention(p, x, n_heads=H, n_kv=KV, head_dim=HD, rope_theta=0.0)
    y_f = at.attention(p_full, x, n_heads=H, n_kv=H, head_dim=HD, rope_theta=0.0)
    np.testing.assert_allclose(y_g, y_f, rtol=1e-4, atol=1e-5)


def test_decode_matches_full(params):
    S = 12
    x = jax.random.normal(jax.random.PRNGKey(4), (2, S, D))
    y_full = at.attention(params, x, n_heads=H, n_kv=KV, head_dim=HD, rope_theta=1e4)
    cache = at.prefill_kv(params, x[:, :S - 1], n_kv=KV, head_dim=HD, rope_theta=1e4)
    cache = at.KVCache(jnp.pad(cache.k, ((0, 0), (0, 1), (0, 0), (0, 0))),
                       jnp.pad(cache.v, ((0, 0), (0, 1), (0, 0), (0, 0))))
    y_dec, new = at.attention_decode(params, x[:, S - 1:], cache,
                                     jnp.full((2,), S - 1, jnp.int32),
                                     n_heads=H, n_kv=KV, head_dim=HD, rope_theta=1e4)
    np.testing.assert_allclose(y_dec[:, 0], y_full[:, -1], rtol=2e-4, atol=2e-4)
    # cache got the new token written at position S-1
    fresh = at.prefill_kv(params, x, n_kv=KV, head_dim=HD, rope_theta=1e4)
    np.testing.assert_allclose(new.k[:, S - 1], fresh.k[:, S - 1], rtol=1e-4, atol=1e-5)


def test_chunked_equals_dense(params, monkeypatch):
    monkeypatch.setattr(at, "CHUNKED_THRESHOLD", 32)
    monkeypatch.setattr(at, "QUERY_CHUNK", 8)
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 64, D))
    y_c = at.attention(params, x, n_heads=H, n_kv=KV, head_dim=HD, rope_theta=1e4)
    monkeypatch.setattr(at, "CHUNKED_THRESHOLD", 1 << 30)
    y_d = at.attention(params, x, n_heads=H, n_kv=KV, head_dim=HD, rope_theta=1e4)
    np.testing.assert_allclose(y_c, y_d, rtol=2e-5, atol=2e-5)


def test_flash_path(params):
    x = jax.random.normal(jax.random.PRNGKey(6), (1, 96, D))
    y_f = at.attention(params, x, n_heads=H, n_kv=KV, head_dim=HD,
                       rope_theta=1e4, use_flash=True)
    y_d = at.attention(params, x, n_heads=H, n_kv=KV, head_dim=HD, rope_theta=1e4)
    np.testing.assert_allclose(y_f, y_d, rtol=2e-4, atol=2e-4)


def test_cross_attention_shape(params):
    x = jax.random.normal(jax.random.PRNGKey(7), (2, 5, D))
    mem = jax.random.normal(jax.random.PRNGKey(8), (2, 9, D))
    y = at.cross_attention(params, x, mem, n_heads=H, n_kv=KV, head_dim=HD)
    assert y.shape == (2, 5, D)


def test_decode_respects_cache_len(params):
    """Tokens beyond cache_len must not influence decode output."""
    S = 16
    cache = at.init_kv_cache(1, S, KV, HD, jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(9), (1, S, KV, HD))
    v = jax.random.normal(jax.random.PRNGKey(10), (1, S, KV, HD))
    x = jax.random.normal(jax.random.PRNGKey(11), (1, 1, D))
    c1 = at.KVCache(k, v)
    garbage = at.KVCache(k.at[:, 9:].set(1e3), v.at[:, 9:].set(-1e3))
    clen = jnp.array([8], jnp.int32)
    y1, _ = at.attention_decode(params, x, c1, clen, n_heads=H, n_kv=KV,
                                head_dim=HD, rope_theta=1e4)
    y2, _ = at.attention_decode(params, x, garbage, clen, n_heads=H, n_kv=KV,
                                head_dim=HD, rope_theta=1e4)
    np.testing.assert_allclose(y1, y2, atol=1e-5)
