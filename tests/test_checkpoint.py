"""Checkpoint manager: atomicity, latest pointer, gc, structure checks."""
import os
import shutil
import tempfile

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpointing import manager as M


def _tree(x=1.0):
    return {"a": jnp.full((4, 4), x), "b": {"c": jnp.arange(6, dtype=jnp.int32)}}


def test_save_restore_roundtrip():
    with tempfile.TemporaryDirectory() as d:
        M.save(d, 10, _tree(2.5), extra={"note": "hi"})
        got, step, extra = M.restore(d, _tree(0.0))
        assert step == 10 and extra["note"] == "hi"
        np.testing.assert_array_equal(np.asarray(got["a"]), 2.5)


def test_latest_pointer_and_gc():
    with tempfile.TemporaryDirectory() as d:
        for s in (10, 20, 30, 40):
            M.save(d, s, _tree(float(s)))
        assert M.latest_step(d) == 40
        M.gc_old(d, keep=2)
        assert M.all_steps(d) == [30, 40]
        got, step, _ = M.restore(d, _tree(0.0))
        assert step == 40


def test_crash_during_write_leaves_previous_intact():
    """A stale .tmp dir (simulated mid-write crash) must not break restore."""
    with tempfile.TemporaryDirectory() as d:
        M.save(d, 10, _tree(1.0))
        os.makedirs(os.path.join(d, "step_00000020.tmp-999"))
        assert M.latest_step(d) == 10
        got, step, _ = M.restore(d, _tree(0.0))
        assert step == 10


def test_missing_key_raises():
    with tempfile.TemporaryDirectory() as d:
        M.save(d, 1, {"a": jnp.zeros((2,))})
        with pytest.raises(KeyError):
            M.restore(d, _tree(0.0))


def test_restore_casts_dtype():
    with tempfile.TemporaryDirectory() as d:
        M.save(d, 1, {"a": jnp.ones((3,), jnp.float32)})
        got, _, _ = M.restore(d, {"a": jnp.zeros((3,), jnp.bfloat16)})
        assert got["a"].dtype == jnp.bfloat16
