"""Cost-model autotuner: rank agreement vs the chiplet simulator, VMEM
budget discipline, feasibility, fallback parity, and the measured cache."""
import json
import os

import pytest

from _hyp import given, settings, st

from repro.core import autotune as at
from repro.core.autotune import (HardwareProfile, Plan, VALIDATION_SWEEP,
                                 feasible_modes, plan_kernel_tiles, plan_moe)
from repro.configs.base import MoEConfig
from repro.sim import modes as sim_modes
from repro.sim.hardware import ModelSpec, scaled

D_MODEL = 512


def _hw(P):
    return {2: scaled(1, 2), 4: scaled(2, 2), 8: scaled(2, 4)}[P]


def _moe(E, de, micro=4):
    return MoEConfig(num_experts=E, top_k=2, d_expert=de, micro_slices=micro)


# ---------------------------------------------------------------------------
# acceptance criterion: >=80% top-choice agreement with the simulator on a
# >=12-point (B, S, E, d_expert, P) sweep
# ---------------------------------------------------------------------------


def test_mode_ranking_agrees_with_simulator():
    assert len(VALIDATION_SWEEP) >= 12
    agree, rows = 0, []
    for (B, S, E, de, P) in VALIDATION_SWEEP:
        hw = _hw(P)
        profile = HardwareProfile.from_chiplet(hw)
        spec = ModelSpec("sweep", D_MODEL, de, E, 2)
        plan = plan_moe(B, S, D_MODEL, _moe(E, de), "swiglu", P,
                        profile=profile, level="analytic")
        sim = sim_modes.rank_modes(hw, spec, B * S, B=B, S=S)
        best = min(sim, key=sim.get)
        agree += plan.mode == best
        rows.append((B, S, E, de, P, plan.mode, best))
    frac = agree / len(VALIDATION_SWEEP)
    assert frac >= 0.8, f"rank agreement {frac:.2f} < 0.8: {rows}"


def test_sweep_exercises_all_three_modes():
    """The referee itself must not be degenerate: each mode wins somewhere."""
    winners = set()
    for (B, S, E, de, P) in VALIDATION_SWEEP:
        sim = sim_modes.rank_modes(_hw(P), ModelSpec("s", D_MODEL, de, E, 2),
                                   B * S, B=B, S=S)
        winners.add(min(sim, key=sim.get))
    assert winners == {"stream", "index", "slice"}


# ---------------------------------------------------------------------------
# VMEM budget + feasibility discipline
# ---------------------------------------------------------------------------


def _check_plan_invariants(B, S, E, de, P, profile):
    plan = plan_moe(B, S, D_MODEL, _moe(E, de), "swiglu", P,
                    profile=profile, level="analytic")
    assert plan.mode in feasible_modes(B, S, P)
    assert plan.vmem_bytes <= profile.vmem_bytes, \
        f"plan {plan} exceeds VMEM budget {profile.vmem_bytes}"
    de_loc = max(1, de // P)
    assert 1 <= plan.micro_slices <= de_loc
    assert de_loc % plan.micro_slices == 0
    return plan


def test_plan_respects_vmem_budget_sweep():
    profile = HardwareProfile.from_chiplet(_hw(4))
    for (B, S, E, de, P) in VALIDATION_SWEEP:
        _check_plan_invariants(B, S, E, de, P,
                               HardwareProfile.from_chiplet(_hw(P)))
    # a deliberately tiny budget still yields a fitting plan
    tight = HardwareProfile(name="tight", peak_flops=profile.peak_flops,
                            mem_bw=profile.mem_bw, link_bw=profile.link_bw,
                            link_latency=profile.link_latency,
                            vmem_bytes=256 * 1024)
    for (B, S, E, de, P) in VALIDATION_SWEEP[:6]:
        _check_plan_invariants(B, S, E, de, P, tight)


@given(B=st.integers(1, 64), S=st.integers(1, 512),
       E=st.sampled_from([4, 8, 16, 32, 64]),
       de=st.sampled_from([64, 128, 256, 512, 1024]),
       P=st.sampled_from([2, 4, 8]))
@settings(max_examples=60, deadline=None)
def test_plan_invariants_property(B, S, E, de, P):
    _check_plan_invariants(B, S, E, de, P, HardwareProfile.from_chiplet(_hw(P)))


def test_infeasible_modes_never_selected():
    profile = HardwareProfile.from_chiplet(_hw(4))
    # S=3 < P and B*S % P != 0 -> only slice lowers
    plan = plan_moe(5, 3, D_MODEL, _moe(16, 512), "swiglu", 4,
                    profile=profile, level="analytic")
    assert plan.mode == "slice"
    with pytest.raises(ValueError):
        plan_moe(5, 3, D_MODEL, _moe(16, 512), "swiglu", 4,
                 profile=profile, mode="stream")


# ---------------------------------------------------------------------------
# fallback ('off') == the legacy pick_mode heuristic
# ---------------------------------------------------------------------------


def test_level_off_matches_legacy_heuristic():
    for (B, S, E, de, P) in VALIDATION_SWEEP:
        moe = _moe(E, de)
        plan = plan_moe(B, S, D_MODEL, moe, "swiglu", P, level="off")
        if S % P == 0 and S >= P:
            legacy = "stream"
        elif (B * S) % P == 0:
            legacy = "index"
        else:
            legacy = "slice"
        assert plan.mode == legacy
        assert plan.micro_slices == moe.micro_slices
        assert plan.source == "fallback"
        assert plan.kernel_opts() == {}


def test_pick_mode_deprecated_one_shot(monkeypatch):
    """pick_mode warns exactly once per process and is gone from the
    repro.core namespace (the registry is the API)."""
    import warnings

    from repro.core import fse_dp
    import repro.core as core_pkg
    assert not hasattr(core_pkg, "pick_mode")
    monkeypatch.setattr(at, "_PICK_MODE_WARNED", False)
    with pytest.warns(DeprecationWarning):
        assert fse_dp.pick_mode(4, 16, 4) == "stream"
    with warnings.catch_warnings():
        warnings.simplefilter("error")          # second call must be silent
        assert at.pick_mode(5, 3, 4) == "slice"


def test_kernel_opts_off_is_empty():
    assert at.kernel_opts_for(8, 16, 64, 32, "swiglu", level="off") == {}


# ---------------------------------------------------------------------------
# tile planner
# ---------------------------------------------------------------------------


def test_tile_planner_prefers_defaults_when_they_fit():
    profile = HardwareProfile.from_tpu()
    tiles = plan_kernel_tiles(8, 64, 256, 128, "swiglu", profile)
    assert tiles["fits"]
    assert tiles["dmodel_tile"] is None          # d_model kept whole
    assert tiles["vmem_bytes"] <= profile.vmem_bytes


def test_tile_planner_shrinks_under_tiny_budget():
    profile = HardwareProfile(name="tiny", peak_flops=1e12, mem_bw=1e11,
                              link_bw=1e11, link_latency=1e-8,
                              vmem_bytes=2 * 2 ** 20)
    tiles = plan_kernel_tiles(8, 256, 1024, 1024, "swiglu", profile)
    big = plan_kernel_tiles(8, 256, 1024, 1024, "swiglu",
                            HardwareProfile.from_tpu())
    assert tiles["vmem_bytes"] < big["vmem_bytes"]


# ---------------------------------------------------------------------------
# measured autotune cache
# ---------------------------------------------------------------------------


def test_measured_cache_roundtrip(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path))
    monkeypatch.setattr(at, "_MEASURED", {})
    monkeypatch.setattr(at, "_CACHE_LOADED", False)
    entry = at.measured_kernel_tiles(2, 8, 32, 16, "swiglu",
                                     dtype_bytes=4, reps=1)
    assert entry["ms"] > 0
    assert "candidates" in entry and len(entry["candidates"]) >= 1
    path = os.path.join(str(tmp_path), "kernel_tiles.json")
    assert os.path.exists(path)
    with open(path) as f:
        disk = json.load(f)
    assert len(disk) == 1
    # second call is a pure cache hit (no re-timing): identical object
    again = at.measured_kernel_tiles(2, 8, 32, 16, "swiglu",
                                     dtype_bytes=4, reps=1)
    assert again is entry


# ---------------------------------------------------------------------------
# plan plumbing
# ---------------------------------------------------------------------------


def test_plan_kernel_opts_roundtrip():
    p = Plan(mode="stream", micro_slices=2, token_tile=64, dexpert_tile=16)
    assert p.kernel_opts() == {"token_tile": 64, "dexpert_tile": 16}
    p = Plan(mode="slice", micro_slices=1)
    assert p.kernel_opts() == {}


def test_forced_mode_plans_cover_all_modes():
    profile = HardwareProfile.from_chiplet(_hw(4))
    for mode in ("stream", "index", "slice"):
        plan = plan_moe(2, 16, D_MODEL, _moe(8, 64), "swiglu", 4,
                        profile=profile, mode=mode)
        assert plan.mode == mode
        assert plan.source == "forced"
