"""Distributed FSE-DP / EP / TP correctness on 8 fake devices
(subprocess — pytest itself stays single-device)."""
import pytest

from conftest import run_distributed_script


@pytest.mark.slow
def test_all_modes_match_oracle():
    out = run_distributed_script("fsedp_modes.py")
    assert "ALL MODES MATCH ORACLE" in out


@pytest.mark.slow
def test_gradients_through_ring():
    out = run_distributed_script("fsedp_grad.py")
    assert "gradients match" in out


@pytest.mark.slow
def test_small_mesh_dryrun_machinery():
    out = run_distributed_script("dryrun_small.py", timeout=1800)
    assert out.count(" ok ") >= 15      # 5 archs × 3 kinds
