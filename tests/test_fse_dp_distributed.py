"""Distributed FSE-DP / EP / TP correctness on 8 fake devices
(subprocess — pytest itself stays single-device)."""
import pytest

from conftest import run_distributed_script


@pytest.mark.slow
def test_all_modes_match_oracle():
    out = run_distributed_script("fsedp_modes.py")
    assert "ALL MODES MATCH ORACLE" in out


@pytest.mark.slow
def test_gradients_through_ring():
    out = run_distributed_script("fsedp_grad.py")
    assert "gradients match" in out


@pytest.mark.slow
def test_kernel_vs_ref_parity_all_modes():
    """use_kernels(True) Pallas path == use_kernels(False) oracle path for
    stream/index/slice, gated and gateless, on 8 fake devices."""
    out = run_distributed_script("fsedp_kernels.py")
    assert "KERNEL PARITY OK" in out


@pytest.mark.slow
def test_plan_driven_dispatch_bit_identical():
    """For each of stream/index/slice, the fse_dp strategy with a forced
    plan is bit-identical to a hand-forced shard_map of the same body,
    and the level='off' fallback reproduces the legacy static dispatch."""
    out = run_distributed_script("fsedp_autotune.py")
    assert "AUTOTUNE PLAN PARITY OK" in out


@pytest.mark.slow
def test_per_layer_spec_overrides_match_forced():
    """ExecutionSpec layer_overrides (fse_dp on even layers, ep on odd)
    == per-layer forced runs, bit for bit, on 8 fake devices."""
    out = run_distributed_script("strategy_overrides.py")
    assert "LAYER OVERRIDES OK" in out


@pytest.mark.slow
def test_dynamic_schedule_bit_identical_to_static():
    """schedule=dynamic (in-graph traced trajectory AND host-built EMA
    schedule) == static, bit for bit, for every distributed family and
    forced FSE-DP mode on 8 fake devices — scheduling changes expert
    execution order only (the paper's virtualization argument)."""
    out = run_distributed_script("dynamic_schedule.py")
    assert "DYNAMIC SCHEDULE PARITY OK" in out


@pytest.mark.slow
def test_small_mesh_dryrun_machinery():
    out = run_distributed_script("dryrun_small.py", timeout=1800)
    assert out.count(" ok ") >= 15      # 5 archs × 3 kinds
