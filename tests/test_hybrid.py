"""Two-tier hybrid hot/cold placement: bit-identity (placement changes
where experts run, never the output), cost-model vs simulator rank
agreement on the committed HYBRID_SWEEP, dynamic EMA repartition vs the
static top-N baseline, and the serving engine's hot-tier trace."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.configs.base import MoEConfig
from repro.core import autotune, gating
from repro.core import strategy as strat
from repro.core.strategy import HYBRID_SWEEP, default_hot
from repro.models import api
from repro.models import moe as moe_mod
from repro.sim import hardware as hwmod
from repro.sim import modes as sim_modes
from repro.sim import workload


def _ndp_hw(P):
    base = {2: hwmod.scaled(1, 2), 4: hwmod.scaled(2, 2),
            8: hwmod.scaled(2, 4)}[P]
    return hwmod.with_ndp(base)


def _loads(E, zipf_s, seed=0):
    if zipf_s <= 0:
        return None
    rng = np.random.default_rng(seed)
    return workload.sample_expert_probs(E, rng, zipf_s=zipf_s)


# ---------------------------------------------------------------------------
# bit-identity: the tier split is placement only
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def tiny_moe():
    moe = MoEConfig(num_experts=8, d_expert=32, top_k=2)
    params = moe_mod.moe_init(jax.random.PRNGKey(0), 16, moe, "swiglu",
                              jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (24, 16), jnp.float32)
    routing = gating.route(params["router"], x, top_k=2)
    return moe, params, x, routing


def test_hybrid_bit_identical_to_capacity(tiny_moe):
    """Every partition width — including the forced single-tier extremes
    H=0 (all near-memory) and H=E (all fast) — produces the exact
    capacity-path output."""
    moe, params, x, routing = tiny_moe
    ref = moe_mod.moe_capacity(params, x, routing, moe, "swiglu")
    for H in range(moe.num_experts + 1):
        got = moe_mod.moe_hybrid(params, x, routing, moe, "swiglu",
                                 hot_experts=H)
        assert jnp.array_equal(ref, got), f"hot_experts={H} diverged"


def test_hybrid_bit_identical_sorted_dispatch(tiny_moe):
    moe, params, x, routing = tiny_moe
    with moe_mod.use_sorted_dispatch(True):
        ref = moe_mod.moe_capacity(params, x, routing, moe, "swiglu")
        got = moe_mod.moe_hybrid(params, x, routing, moe, "swiglu",
                                 hot_experts=3)
    assert jnp.array_equal(ref, got)


def test_hybrid_strategy_matches_capacity_strategy(tiny_moe):
    moe, params, x, _ = tiny_moe
    xb = x[None]
    y_cap, aux_cap = strat.get_strategy("capacity").execute(
        params, xb, moe, "swiglu")
    y_hyb, aux_hyb = strat.get_strategy("hybrid").execute(
        params, xb, moe, "swiglu")
    assert jnp.array_equal(y_cap, y_hyb)
    assert float(aux_cap) == float(aux_hyb)


def test_hybrid_bit_identical_with_host_schedule(tiny_moe):
    """A host EMA schedule only reorders/partitions — same outputs."""
    from repro.core import trajectory
    moe, params, x, routing = tiny_moe
    counts = np.asarray(gating.expert_token_counts(routing))
    sched = trajectory.build_schedule(counts, policy="dynamic")
    ref = moe_mod.moe_capacity(params, x, routing, moe, "swiglu")
    got = moe_mod.moe_hybrid(params, x, routing, moe, "swiglu",
                             hot_experts=2, schedule=sched)
    assert jnp.array_equal(ref, got)


# ---------------------------------------------------------------------------
# the two-tier hardware model + registry plumbing
# ---------------------------------------------------------------------------


def test_hybrid_registered_and_plan_carries_hot_width():
    assert "hybrid" in strat.available()
    assert strat.FAMILIES == strat.BASE_FAMILIES + ("hybrid",)
    hw = _ndp_hw(4)
    profile = autotune.HardwareProfile.from_chiplet(hw)
    assert profile.ndp_flops == hw.ndp.tops
    assert profile.ndp_bw == hw.ndp.gbps
    moe = MoEConfig(num_experts=64, d_expert=1408, top_k=6)
    ctx = strat.StrategyContext(B=2, S=1, d_model=512, moe=moe,
                                activation="swiglu", P=4, profile=profile)
    plan = strat.get_strategy("hybrid").plan(ctx)
    assert plan.family == "hybrid"
    assert plan.hot_experts is not None
    assert 0 <= plan.hot_experts <= moe.num_experts
    assert plan.predicted_s > 0


def test_hybrid_out_of_race_on_homogeneous_hardware():
    """No NDP tier -> family_costs has no hybrid row; hybrid_cost and
    simulate_hybrid refuse; the strategy still executes (placement is
    a no-op for numerics)."""
    profile = autotune.HardwareProfile.from_chiplet(hwmod.PROTOTYPE_2X2)
    moe = MoEConfig(num_experts=16, d_expert=512, top_k=2)
    costs = strat.family_costs(8, 1, 512, moe, "swiglu", 4, profile=profile)
    assert "hybrid" not in costs
    with pytest.raises(ValueError):
        autotune.hybrid_cost(8, 1, 512, 16, 512, 2, 1.25, 3, 4, profile)
    with pytest.raises(ValueError):
        sim_modes.simulate_hybrid(hwmod.PROTOTYPE_2X2,
                                  hwmod.ModelSpec("s", 512, 512, 16, 2), 8)


def test_hybrid_cost_prefers_fewer_hot_when_weight_bound():
    """Low-batch decode is DDR-bound: the optimal partition pushes cold
    experts near memory instead of streaming everything."""
    profile = autotune.HardwareProfile.from_chiplet(_ndp_hw(4))
    all_fast = autotune.hybrid_cost(2, 1, 512, 64, 1408, 6, 1.25, 3, 4,
                                    profile, hot_n=64)["total_s"]
    best = autotune.hybrid_cost(2, 1, 512, 64, 1408, 6, 1.25, 3, 4,
                                profile)
    assert best["total_s"] < all_fast
    assert best["hot_n"] < 64


# ---------------------------------------------------------------------------
# cost model vs simulator referee on the committed sweep
# ---------------------------------------------------------------------------


def test_hybrid_rank_agreement_on_sweep():
    """Cost-model family winner agrees with the simulator referee on
    >=80% of HYBRID_SWEEP, and hybrid / EP / FSE-DP each win at least
    one simulated point (the race is not degenerate)."""
    agree, rows, winners = 0, [], set()
    for (B, S, E, de, P, zs) in HYBRID_SWEEP:
        hw = _ndp_hw(P)
        profile = autotune.HardwareProfile.from_chiplet(hw)
        moe = MoEConfig(num_experts=E, top_k=2, d_expert=de)
        loads = _loads(E, zs)
        lt = None if loads is None else tuple(float(v) for v in loads)
        costs = strat.family_costs(B, S, 512, moe, "swiglu", P,
                                   profile=profile, load=lt)
        assert "hybrid" in costs
        chosen = strat.pick_family(costs)
        sim = sim_modes.rank_families(hw, hwmod.ModelSpec("s", 512, de, E, 2),
                                      B * S, B=B, S=S, loads=loads)
        assert "hybrid" in sim
        best = min((f for f in strat.FAMILIES if f in sim),
                   key=lambda f: sim[f])
        winners.add(best)
        agree += chosen == best
        rows.append((B, S, E, de, P, zs, chosen, best))
    frac = agree / len(HYBRID_SWEEP)
    assert frac >= 0.8, f"hybrid rank agreement {frac:.2f} < 0.8: {rows}"
    assert {"hybrid", "ep", "fse_dp"} <= winners, \
        f"sweep is degenerate — sim winners {winners}: {rows}"


def test_dynamic_repartition_beats_static_topn():
    """On Zipf-skewed load in the compute-sensitive token regime, the
    load-aware partition (the engine's EMA repartition, idealized)
    beats the static id-prefix top-N baseline; the free per-step sweep
    is at least as good again."""
    hw = _ndp_hw(4)
    wins = 0
    cases = [(64, 1408, 256, 1.2), (64, 1408, 512, 1.2),
             (64, 768, 512, 1.4), (32, 1408, 256, 1.2)]
    for (E, de, tokens, zs) in cases:
        spec = hwmod.ModelSpec("s", 512, de, E, 2)
        loads = _loads(E, zs, seed=7)
        N = default_hot(E)
        static = sim_modes.simulate_hybrid(hw, spec, tokens, loads=loads,
                                           hot_ids=range(N)).latency
        dyn_ids = np.argsort(-loads, kind="stable")[:N]
        dynamic = sim_modes.simulate_hybrid(hw, spec, tokens, loads=loads,
                                            hot_ids=dyn_ids).latency
        sweep = sim_modes.simulate_hybrid(hw, spec, tokens,
                                          loads=loads).latency
        assert sweep <= dynamic + 1e-12
        wins += dynamic < static
    assert wins == len(cases), \
        f"dynamic repartition won only {wins}/{len(cases)}"


def test_replay_trace_prices_hot_records():
    """Trace records carrying ``hot`` ids replay through the two-tier
    referee on NDP hardware and fall back to the homogeneous path
    otherwise."""
    hw = _ndp_hw(4)
    spec = hwmod.ModelSpec("s", 512, 1408, 8, 2)
    trace = [{"iter": 0, "layer": 0, "schedule": "dynamic",
              "counts": [5, 3, 0, 0, 1, 0, 0, 0], "hot": [0, 1],
              "order": [0, 1, 4, 2, 3, 5, 6, 7]}]
    t_ndp = sim_modes.replay_trace(hw, spec, trace)
    t_flat = sim_modes.replay_trace(hwmod.PROTOTYPE_2X2, spec, trace)
    assert t_ndp > 0 and t_flat > 0
    assert t_ndp != t_flat


# ---------------------------------------------------------------------------
# serving engine plumbing (trace hot ids + modeled clock)
# ---------------------------------------------------------------------------


def test_layer_s_two_tier_pricing():
    cfg = reduced_config("granite-moe-1b-a400m").replace(dtype="float32")
    flat = autotune.ServingCostModel.from_config(cfg)
    ndp = autotune.ServingCostModel.from_config(
        cfg, profile=autotune.HardwareProfile.from_chiplet_array(
            hwmod.with_ndp()))
    counts = [6, 3, 1, 0] + [0] * (cfg.moe.num_experts - 4)
    hot = [0, 1]
    # homogeneous profile: hot is accounting-inert
    assert flat.layer_s(counts, dynamic=True, hot=hot) == \
        flat.layer_s(counts, dynamic=True)
    # two-tier profile: the partition changes the modeled seconds
    assert ndp.layer_s(counts, dynamic=True, hot=hot) != \
        ndp.layer_s(counts, dynamic=True)
    assert ndp.layer_s(counts, dynamic=True, hot=hot) > 0


def test_engine_records_hot_partition():
    """A hybrid-spec engine stamps each MoE trace record with the
    fast-tier ``hot`` ids (EMA repartition, like ``resident``) and
    emits the same tokens as the capacity strategy."""
    from repro.serving import Engine, ServeConfig
    cfg = reduced_config("granite-moe-1b-a400m").replace(dtype="float32")
    params = api.init_params(jax.random.PRNGKey(0), cfg)

    def run(strategy, hot=None):
        spec = strat.ExecutionSpec(strategy=strategy, schedule="dynamic")
        eng = Engine(params, cfg, ServeConfig(
            max_batch=2, max_ctx=32, spec=spec, hot_experts=hot))
        rid = eng.submit([1, 2, 3, 4], max_new=4)
        outs = eng.run()
        return eng, outs[rid]

    eng_h, toks_h = run("hybrid", hot=2)
    eng_c, toks_c = run("capacity")
    assert toks_h == toks_c                      # placement-only
    moe_recs = [r for r in eng_h.trace if "counts" in r]
    assert moe_recs and all("hot" in r for r in moe_recs)
    assert all(len(r["hot"]) == 2 for r in moe_recs)
    assert "hybrid_repartitions" in eng_h.stats
    # capacity engine never stamps hot ids
    assert all("hot" not in r for r in eng_c.trace)
