"""Primitive layers: norms, RoPE, activations — unit + hypothesis."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.models import layers as L


def test_rmsnorm_unit_scale():
    p = L.rmsnorm_init(16)
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 16)) * 10
    y = L.rmsnorm(p, x)
    rms = np.sqrt(np.mean(np.asarray(y) ** 2, -1))
    np.testing.assert_allclose(rms, 1.0, rtol=1e-3)


def test_layernorm_moments():
    p = L.layernorm_init(32)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 32)) * 3 + 5
    y = np.asarray(L.layernorm(p, x))
    np.testing.assert_allclose(y.mean(-1), 0.0, atol=1e-4)
    np.testing.assert_allclose(y.std(-1), 1.0, rtol=1e-2)


def test_rope_preserves_norm():
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 8, 4, 16))
    pos = jnp.arange(8)[None, :]
    y = L.apply_rope(x, pos, 10000.0)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(y), axis=-1),
                               np.linalg.norm(np.asarray(x), axis=-1), rtol=1e-4)


def test_rope_relative_shift():
    """<q_i, k_j> after RoPE depends only on i - j."""
    hd = 32
    q = jax.random.normal(jax.random.PRNGKey(3), (1, 1, 1, hd))
    k = jax.random.normal(jax.random.PRNGKey(4), (1, 1, 1, hd))
    def dot_at(i, j):
        qi = L.apply_rope(q, jnp.array([[i]]), 1e4)
        kj = L.apply_rope(k, jnp.array([[j]]), 1e4)
        return float(jnp.sum(qi * kj))
    assert abs(dot_at(5, 3) - dot_at(12, 10)) < 1e-4
    assert abs(dot_at(5, 3) - dot_at(6, 3)) > 1e-6   # actually differs by pos


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 64))
def test_relu2_nonneg(d):
    f = L.activation_fn("relu2")
    x = jax.random.normal(jax.random.PRNGKey(d), (d,))
    assert bool(jnp.all(f(x) >= 0))


def test_sinusoidal_shape():
    enc = L.sinusoidal_positions(10, 8)
    assert enc.shape == (10, 8)
    assert bool(jnp.all(jnp.abs(enc) <= 1.0 + 1e-6))
