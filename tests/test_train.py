"""Trainer: convergence, crash/restart continuity, gradient compression."""
import os
import tempfile

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.data import DataConfig
from repro.training import TrainConfig, train

CFG = reduced_config("granite-moe-1b-a400m").replace(dtype="float32")
DCFG = DataConfig(vocab_size=CFG.vocab_size, seq_len=32, global_batch=8, seed=3)


@pytest.mark.slow
def test_loss_decreases():
    r = train(CFG, DCFG, TrainConfig(total_steps=60, warmup=5, lr=3e-3,
                                     log_every=10), seed=0)
    first, last = r.losses[0][1], r.losses[-1][1]
    assert last < first - 0.5, (first, last)


@pytest.mark.slow
def test_crash_resume_bitwise():
    """Train 30 straight vs crash-at-20 + resume: identical final loss."""
    tc = dict(total_steps=30, warmup=5, lr=3e-3, ckpt_every=10, log_every=1)
    with tempfile.TemporaryDirectory() as d1:
        r_straight = train(CFG, DCFG, TrainConfig(ckpt_dir=d1, **tc), seed=0)
    with tempfile.TemporaryDirectory() as d2:
        with pytest.raises(RuntimeError, match="preemption"):
            train(CFG, DCFG, TrainConfig(ckpt_dir=d2, **tc), seed=0,
                  crash_at_step=20)
        r_resumed = train(CFG, DCFG, TrainConfig(ckpt_dir=d2, **tc), seed=0)
    assert r_resumed.resumed_from == 20
    np.testing.assert_allclose(r_straight.losses[-1][1],
                               r_resumed.losses[-1][1], rtol=1e-5)


@pytest.mark.slow
def test_grad_compression_converges():
    """int8 + error feedback stays within tolerance of fp32 training."""
    base = train(CFG, DCFG, TrainConfig(total_steps=40, warmup=5, lr=3e-3,
                                        log_every=39), seed=0)
    comp = train(CFG, DCFG, TrainConfig(total_steps=40, warmup=5, lr=3e-3,
                                        log_every=39, grad_compress_bits=8), seed=0)
    l_base, l_comp = base.losses[-1][1], comp.losses[-1][1]
    assert abs(l_base - l_comp) < 0.35, (l_base, l_comp)


def test_compress_roundtrip_error_feedback():
    import jax
    from repro.optim import compress
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(64, 64)),
                          jnp.float32)}
    deq, res = compress.compress_tree(g)
    # error feedback: residual == exact quantization error
    np.testing.assert_allclose(np.asarray(g["w"] - deq["w"]),
                               np.asarray(res["w"]), rtol=1e-6, atol=1e-7)
    # second step with zero grad flushes the residual
    z = {"w": jnp.zeros((64, 64), jnp.float32)}
    deq2, res2 = compress.compress_tree(z, res)
    np.testing.assert_allclose(np.asarray(deq2["w"] + res2["w"]),
                               np.asarray(res["w"]), rtol=1e-5, atol=1e-6)
