"""Paged KV/SSM state pool: page accounting, hash-chain prefix cache,
preemption/restore bit-identity, Mamba2 snapshot exactness, traffic
mixes."""
from collections import Counter

import jax
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.kernels.ops import use_kernels
from repro.models import api
from repro.serving import (Engine, PoolExhausted, QueueFullError, Scheduler,
                           SchedulerConfig, ServeConfig, StatePool,
                           TrafficConfig, hash_chain, make_traffic,
                           run_closed_loop)
from repro.serving import statepool
from repro.sim.workload import trace_expert_totals


@pytest.fixture(scope="module")
def setup():
    cfg = reduced_config("granite-moe-1b-a400m").replace(dtype="float32")
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


@pytest.fixture(scope="module")
def jamba():
    cfg = reduced_config("jamba-v0.1-52b").replace(dtype="float32")
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


# ---------------------------------------------------------------------------
# pool unit tests (host-side metadata only, no model)
# ---------------------------------------------------------------------------


def test_hash_chain_content_addressing():
    a = hash_chain([1, 2, 3, 4])
    b = hash_chain([1, 2, 3, 4])
    c = hash_chain([1, 2, 9, 4])
    assert len(a) == 4 and a == b
    # keys are a chain: equal up to the divergence point, distinct after
    assert a[:2] == c[:2]
    assert a[2] != c[2] and a[3] != c[3]


def test_pool_alloc_release_accounting():
    pool = StatePool(max_batch=2, max_ctx=16, page_size=4,
                     bytes_per_page=100)
    pool.ensure(0, 5)                     # ceil(5/4) = 2 pages
    assert pool.pages_in_use() == 2
    pool.ensure(0, 5)                     # idempotent
    assert pool.pages_in_use() == 2
    pool.ensure(0, 9)                     # grows to 3
    assert pool.pages_in_use() == 3
    assert pool.stats["resident_state_bytes"] == 300
    pool.release_slot(0)
    assert pool.pages_in_use() == 0
    assert pool.stats["pool_peak_pages"] == 3
    assert pool.stats["peak_resident_state_bytes"] == 300
    # the table row is what the engine gathers through: distinct pages
    with pytest.raises(ValueError, match="too small"):
        StatePool(max_batch=2, max_ctx=16, page_size=4, num_pages=7)


def test_pool_exhaustion_raises_typed_error():
    # exactly one slot's worth of pages per slot, nothing spare
    pool = StatePool(max_batch=2, max_ctx=8, page_size=4, num_pages=4)
    pool.ensure(0, 8)
    pool.ensure(1, 8)
    held = pool.detach_slot(0)            # a preemption handle holds these
    assert len(held) == 2 and pool.pages_in_use() == 4
    with pytest.raises(PoolExhausted):
        pool.ensure(0, 8)                 # nothing free, nothing evictable
    pool.attach_pages(0, held)            # handle restores; no new pages
    assert pool.pages_in_use() == 4


def test_prefix_register_lookup_attach_shares_pages():
    pool = StatePool(max_batch=2, max_ctx=16, page_size=4)
    toks = list(range(1, 13))
    keys = hash_chain(toks)
    pool.ensure(0, 6)                     # 1 full page + 2-token tail
    plan = pool.register_prefix(keys[5], 6, 0)
    assert plan is not None               # tail page copy-on-write
    assert pool.pages_in_use() == 3       # slot's 2 + entry's tail copy
    # longest-prefix lookup, capped at len(prompt) - 1
    hit = pool.lookup_prefix(keys, max_len=11)
    assert hit is not None and hit.length == 6 and hit.hits == 1
    assert pool.lookup_prefix(hash_chain([7, 7, 7]), max_len=2) is None
    plan = pool.attach_prefix(hit, 1)
    assert plan is not None               # slot 1 gets its own tail copy
    assert pool.stats["cache_hits"] == 1
    assert pool.stats["prefill_tokens_saved"] == 6
    # shared full page survives both slot releases via the entry's ref
    pool.release_slot(0)
    pool.release_slot(1)
    assert pool.pages_in_use() == 2       # entry: full page + tail copy


def test_prefix_lru_eviction():
    pool = StatePool(max_batch=1, max_ctx=16, page_size=4,
                     max_prefix_entries=2)
    pool.ensure(0, 8)
    ka = hash_chain([1, 2, 3, 4, 5, 6, 7, 8])
    kb = hash_chain([8, 7, 6, 5, 4, 3, 2, 1])
    kc = hash_chain([2, 2, 2, 2, 2, 2, 2, 2])
    pool.register_prefix(ka[7], 8, 0)
    pool.register_prefix(kb[7], 8, 0)
    pool.register_prefix(kc[7], 8, 0)     # over capacity: evicts ka (LRU)
    assert pool.stats["cache_evictions"] == 1
    assert pool.lookup_prefix(ka, max_len=8) is None
    assert pool.lookup_prefix(kb, max_len=8) is not None
    assert pool.lookup_prefix(kc, max_len=8) is not None


# ---------------------------------------------------------------------------
# engine integration (granite reduced: attention + MoE, no SSM)
# ---------------------------------------------------------------------------


def test_queue_full_error_is_typed(setup):
    cfg, params = setup
    eng = Engine(params, cfg, ServeConfig(max_batch=1, max_ctx=16))
    eng.submit([1, 2, 3], max_new=2)
    with pytest.raises(QueueFullError):
        eng.submit([4, 5], max_new=2)
    with pytest.raises(QueueFullError):
        eng.submit_chunked([4, 5], max_new=2)
    # a QueueFullError IS a RuntimeError: pre-pool callers that caught
    # the untyped error keep working
    assert issubclass(QueueFullError, RuntimeError)


def test_engine_stats_expose_pool_counters(setup):
    cfg, params = setup
    for fused in (True, False):
        eng = Engine(params, cfg, ServeConfig(max_batch=2, max_ctx=16,
                                              fused=fused))
        for k in ("pool_pages", "pool_pages_in_use", "pool_peak_pages",
                  "resident_state_bytes", "peak_resident_state_bytes",
                  "cache_hits", "cache_misses", "cache_evictions",
                  "prefill_tokens_saved", "preemptions", "restores"):
            assert k in eng.stats, (fused, k)
        # engine stats and pool stats are one dict: pool mutations land
        # directly in Engine.stats on both paths
        assert eng.stats is eng.pool.stats


@pytest.mark.parametrize("fused", [True, False], ids=["fused", "legacy"])
def test_prefix_cache_hit_bit_identical(setup, fused):
    cfg, params = setup
    prompt = [5, 6, 7, 8, 9, 10, 11]

    def run_twice(prefix_cache):
        eng = Engine(params, cfg, ServeConfig(
            max_batch=2, max_ctx=24, chunk_tokens=4, fused=fused,
            prefix_cache=prefix_cache))
        r0 = eng.submit_chunked(list(prompt), max_new=4)
        o0 = eng.run()[r0]
        r1 = eng.submit_chunked(list(prompt), max_new=4)
        o1 = eng.run()[r1]
        return eng, o0, o1

    eng_cold, a_cold, b_cold = run_twice(False)
    eng_hot, a_hot, b_hot = run_twice(True)
    # cached admission changes compute, never tokens
    assert (a_hot, b_hot) == (a_cold, b_cold)
    assert eng_hot.stats["cache_hits"] == 1
    assert eng_hot.stats["cache_misses"] == 1
    # chunk boundary at 4 is the longest cached prefix under len-1 = 6
    assert eng_hot.stats["prefill_tokens_saved"] == 4
    assert eng_hot.stats["prefill_tokens"] \
        == eng_cold.stats["prefill_tokens"] - 4
    hits = [r for r in eng_hot.trace if r.get("event") == "cache_hit"]
    assert len(hits) == 1 and hits[0]["cached_tokens"] == 4


@pytest.mark.parametrize("fused", [True, False], ids=["fused", "legacy"])
def test_closed_loop_preempt_and_cache_match_unbounded(setup, fused):
    """The acceptance property: a closed-loop run with preemptions (and
    then cache hits) emits the same tokens as the unbounded run; the
    preemption-only run also replays to the same per-layer expert totals
    (same tokens -> same gating -> same aggregate trace)."""
    cfg, params = setup
    tcfg = TrafficConfig(num_requests=8, rate=2.0, avg_prompt=8,
                         max_prompt=16, min_new=2, max_new=4,
                         vocab=cfg.vocab_size, seed=0,
                         mix="poisson+zipf_prefix", num_prefixes=2,
                         prefix_len=6)
    traffic = make_traffic(tcfg)

    def go(prefix_cache, depth):
        eng = Engine(params, cfg, ServeConfig(
            max_batch=2, max_ctx=24, chunk_tokens=4, fused=fused,
            prefix_cache=prefix_cache, preempt_queue_depth=depth))
        sched = Scheduler(eng, SchedulerConfig(queue_capacity=64))
        return eng, run_closed_loop(sched, traffic)

    eng_ref, res_ref = go(False, None)
    assert res_ref["metrics"].completed == 8 and not res_ref["dropped"]

    eng_pre, res_pre = go(False, 0)       # forced preemption, no cache
    assert res_pre["metrics"].preemptions > 0
    assert res_pre["metrics"].restores == res_pre["metrics"].preemptions
    assert res_pre["metrics"].completed == 8 and not res_pre["dropped"]
    assert res_pre["outputs"] == res_ref["outputs"]
    tot_ref = trace_expert_totals(eng_ref.trace)
    tot_pre = trace_expert_totals(eng_pre.trace)
    assert set(tot_ref) == set(tot_pre)
    for layer in tot_ref:
        assert (tot_ref[layer] == tot_pre[layer]).all(), layer

    eng_both, res_both = go(True, 0)      # preemption + prefix caching
    assert res_both["outputs"] == res_ref["outputs"]
    assert res_both["metrics"].cache_hits > 0
    assert res_both["metrics"].preemptions > 0
    assert res_both["metrics"].completed == 8
    assert eng_both.stats["prefill_tokens"] < eng_ref.stats["prefill_tokens"]


# ---------------------------------------------------------------------------
# Mamba2 snapshot -> evict -> restore exactness (jamba reduced: hybrid
# attention / SSM / MoE stack)
# ---------------------------------------------------------------------------

JAMBA_PROMPTS = ((1, 2, 3, 4, 5), (9, 8, 7))


def _ssm_equal(a: tuple, b: tuple) -> bool:
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(la) == len(lb) > 0 and all(
        np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(la, lb))


@pytest.mark.parametrize("kernels", [False, True], ids=["nokern", "kern"])
@pytest.mark.parametrize("schedule", [None, "dynamic"],
                         ids=["static", "dynamic"])
@pytest.mark.parametrize("fused", [True, False], ids=["fused", "legacy"])
def test_mamba_snapshot_evict_restore_exact(jamba, kernels, schedule, fused):
    """Property: snapshot -> evict -> (slot reused by another request)
    -> restore is bit-exact on the SSM state, and the subsequent decode
    is bit-identical to a never-preempted run."""
    cfg, params = jamba
    spec = {"strategy": "capacity"}
    if schedule:
        spec["schedule"] = schedule
    scfg = ServeConfig(max_batch=2, max_ctx=16, chunk_tokens=4,
                       fused=fused, spec=spec)
    with use_kernels(kernels):
        ref = Engine(params, cfg, scfg)
        rids = [ref.submit_chunked(list(p), max_new=3) for p in JAMBA_PROMPTS]
        ref_outs = ref.run()

        eng = Engine(params, cfg, scfg)
        aids = [eng.submit_chunked(list(p), max_new=3) for p in JAMBA_PROMPTS]
        eng.step()
        eng.step()
        # the short prompt finishes inside two steps; the long one is
        # mid-generation with real conv/ssm state — that's the victim
        victim = aids[0]
        r = eng.requests[victim]
        assert not r.done and r.generated, "victim must be mid-decode"
        slot = r.slot
        live = statepool.snapshot_ssm(eng.caches, slot)
        handle = eng.preempt(victim)
        # the handle snapshots by value, bitwise
        assert handle.ssm != () and _ssm_equal(handle.ssm, live)
        # dirty the freed slot: an intruder request prefills and decodes
        # through the very rows the snapshot came from
        eng.submit_chunked([3, 1, 2], max_new=2)
        eng.run()
        assert eng.restore(handle) == victim
        slot2 = eng.requests[victim].slot
        assert _ssm_equal(statepool.snapshot_ssm(eng.caches, slot2),
                          handle.ssm)
        outs = eng.run()
    assert outs[victim] == ref_outs[rids[0]]
    assert outs[aids[1]] == ref_outs[rids[1]]
    assert eng.stats["preemptions"] == 1 and eng.stats["restores"] == 1


# ---------------------------------------------------------------------------
# traffic mixes
# ---------------------------------------------------------------------------


def test_zipf_prefix_mix_shares_prompt_heads():
    tcfg = TrafficConfig(num_requests=12, mix="poisson+zipf_prefix",
                         num_prefixes=2, prefix_len=6, max_prompt=24,
                         vocab=64, seed=0)
    reqs = make_traffic(tcfg)
    heads = [tuple(r.prompt[:6]) for r in reqs]
    assert len(set(heads)) <= 2                       # drawn from 2 prefixes
    assert Counter(heads).most_common(1)[0][1] >= 2   # genuinely shared
    assert all(len(r.prompt) > 6 for r in reqs)       # >=1 private token
    assert all(len(r.prompt) <= tcfg.max_prompt for r in reqs)


def test_prefix_len_capped_below_max_prompt():
    tcfg = TrafficConfig(num_requests=4, mix="poisson+zipf_prefix",
                         num_prefixes=2, prefix_len=64, max_prompt=8,
                         vocab=64, seed=0)
    for r in make_traffic(tcfg):
        assert len(r.prompt) <= 8


def test_poisson_mix_is_the_default_stream():
    base = make_traffic(TrafficConfig(num_requests=6, seed=3))
    explicit = make_traffic(TrafficConfig(num_requests=6, seed=3,
                                          mix="poisson"))
    assert [(r.rid, r.arrival, r.prompt, r.max_new) for r in base] \
        == [(r.rid, r.arrival, r.prompt, r.max_new) for r in explicit]


def test_diurnal_mix_modulates_arrivals_only():
    base = make_traffic(TrafficConfig(num_requests=8, seed=1))
    burst = make_traffic(TrafficConfig(num_requests=8, seed=1,
                                       mix="poisson+diurnal"))
    # same prompts in the same order (same rng draw count) ...
    assert [r.prompt for r in base] == [r.prompt for r in burst]
    # ... on a different arrival clock
    assert [r.arrival for r in base] != [r.arrival for r in burst]
    for r in burst:
        assert r.arrival >= 0.0


def test_unknown_mix_component_rejected():
    with pytest.raises(ValueError, match="unknown traffic mix"):
        TrafficConfig(mix="poisson+lunar")


def test_detach_attach_ssm_accounting_pure_attention():
    """Regression: ``detach_slot`` used to bump ``_ssm_rows_held``
    unconditionally, so a pure-attention model (no SSM state to
    snapshot) leaked phantom SSM bytes into ``resident_state_bytes``
    across every preempt/restore cycle."""
    pool = StatePool(max_batch=2, max_ctx=16, page_size=4,
                     bytes_per_page=100, ssm_bytes_per_row=1000)
    pool.ensure(0, 8)
    base = pool.stats["resident_state_bytes"]
    assert base == 200                       # 2 pages, zero SSM rows

    # attention-only preemption: handle carries pages but no SSM snapshot
    held = pool.detach_slot(0, has_ssm=False)
    assert pool._ssm_rows_held == 0
    assert pool.stats["resident_state_bytes"] == base
    pool.attach_pages(0, held, has_ssm=False)
    assert pool._ssm_rows_held == 0
    assert pool.stats["resident_state_bytes"] == base

    # hybrid-model preemption: the snapshot is real and is accounted
    held = pool.detach_slot(0, has_ssm=True)
    assert pool._ssm_rows_held == 1
    assert pool.stats["resident_state_bytes"] == base + 1000
    pool.attach_pages(0, held, has_ssm=True)
    assert pool._ssm_rows_held == 0
    assert pool.stats["resident_state_bytes"] == base

    # drop_handle only releases rows the handle actually snapshot
    from repro.serving.statepool import PreemptedState
    pool.drop_handle(PreemptedState(request=None, page_ids=[],
                                    cache_len=0, ssm=()))
    assert pool._ssm_rows_held == 0          # ssm=() -> no decrement
