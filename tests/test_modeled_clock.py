"""Modeled wall clock: the engine's closed-form chiplet-array seconds
(autotune.ServingCostModel) vs the sim.modes event-loop referee, and the
scheduler's modeled TTFT/TPOT plumbing."""
import jax
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.core.autotune import (HardwareProfile, ServingCostModel,
                                 streaming_layer_cost)
from repro.models import api
from repro.serving import (Engine, ServeConfig, Scheduler, SchedulerConfig,
                           TrafficConfig, make_traffic, run_closed_loop)
from repro.sim.hardware import PROTOTYPE_2X2, spec_from_config
from repro.sim.modes import replay_trace, simulate_trajectory

# stated agreement tolerances, model vs referee (measured headroom on
# the reduced granite workload: <=1.5% per record, <=0.5% aggregate)
PER_RECORD_TOL = 0.05
AGGREGATE_TOL = 0.02


@pytest.fixture(scope="module")
def setup():
    cfg = reduced_config("granite-moe-1b-a400m").replace(dtype="float32")
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _traced_run(cfg, params, schedule=None):
    spec = {"strategy": "capacity"}
    if schedule:
        spec["schedule"] = schedule
    eng = Engine(params, cfg, ServeConfig(max_batch=4, max_ctx=48,
                                          chunk_tokens=4, spec=spec))
    for p in ((1, 2, 3, 4), (9, 8, 7), (5, 5, 5, 5, 5)):
        eng.submit_chunked(list(p), max_new=6)
    eng.run()
    return eng


@pytest.mark.parametrize("schedule", [None, "dynamic"],
                         ids=["static", "dynamic"])
def test_model_agrees_with_referee(setup, schedule):
    """Every trace record's closed-form modeled_s must agree with the
    discrete expert-flow event loop (sim.modes.simulate_trajectory)
    within PER_RECORD_TOL, and the trace total within AGGREGATE_TOL —
    the two are deliberately different constructions, so this is a real
    cross-check, not an identity."""
    cfg, params = setup
    eng = _traced_run(cfg, params, schedule)
    spec = spec_from_config(eng.cfg)
    cf = eng.cfg.moe.capacity_factor
    assert eng.trace, "no workload trace"
    checked = 0
    for rec in eng.trace:
        counts = np.asarray(rec["counts"], np.float64)
        if counts.sum() <= 0:
            continue
        assert rec["modeled_s"] > 0
        if rec["schedule"] == "dynamic":
            ref = simulate_trajectory(
                PROTOTYPE_2X2, spec, counts,
                order=rec.get("trajectory") or rec["order"],
                capacity_factor=cf)
        else:
            ref = simulate_trajectory(PROTOTYPE_2X2, spec, counts,
                                      padded=True, capacity_factor=cf)
        assert abs(rec["modeled_s"] - ref) <= PER_RECORD_TOL * ref, \
            (rec["layer"], rec["phase"], rec["modeled_s"], ref)
        checked += 1
    assert checked > 0
    total_m = sum(rec["modeled_s"] for rec in eng.trace)
    total_r = replay_trace(PROTOTYPE_2X2, spec, eng.trace,
                           capacity_factor=cf)
    assert abs(total_m - total_r) <= AGGREGATE_TOL * total_r


def test_streaming_cost_exact_at_extremes():
    """The closed form is exact against the event loop's structure at
    both regimes: compute-bound => fill + compute chain; DDR-bound =>
    active serial weight loads."""
    E, C, d, de, n_mats = 8, 4, 64, 128, 2
    eb = float(n_mats * d * de * 2)

    def profile(flops, bw):
        return HardwareProfile(name="synthetic", peak_flops=flops,
                               mem_bw=bw, link_bw=bw, link_latency=0.0,
                               vmem_bytes=1 << 20)

    ddr_bound = profile(1e18, 1e9)
    c = streaming_layer_cost(E, C, d, de, n_mats, E * C, ddr_bound)
    assert c["total_s"] == pytest.approx(E * eb / 1e9, rel=1e-12)
    comp_bound = profile(1e9, 1e18)
    c = streaming_layer_cost(E, C, d, de, n_mats, E * C, comp_bound)
    assert c["total_s"] == pytest.approx(c["t_fill_s"] + c["t_comp_s"],
                                         rel=1e-12)


def test_dynamic_never_costs_more_than_static():
    """For any observed gating, pricing the observed load (dynamic) can
    only shed padded rows and idle weight loads vs the shape-only plan."""
    cfg = reduced_config("granite-moe-1b-a400m")
    cm = ServingCostModel.from_config(cfg)
    rng = np.random.default_rng(0)
    for _ in range(20):
        counts = rng.integers(0, 6, size=cfg.moe.num_experts)
        if counts.sum() == 0:
            continue
        dyn = cm.layer_s(counts, dynamic=True)
        stat = cm.layer_s(counts, dynamic=False)
        assert dyn <= stat + 1e-18, (counts, dyn, stat)


def _closed_loop(cfg, params, clock):
    traffic = make_traffic(TrafficConfig(
        num_requests=6, rate=0.8, avg_prompt=8, max_prompt=16, min_new=2,
        max_new=4, vocab=cfg.vocab_size, seed=0))
    eng = Engine(params, cfg, ServeConfig(max_batch=4, max_ctx=32,
                                          chunk_tokens=4))
    sched = Scheduler(eng, SchedulerConfig(queue_capacity=16), clock=clock)
    res = run_closed_loop(sched, traffic)
    return eng, sched, res


def test_scheduler_modeled_metrics_always_on(setup):
    """Whatever the primary clock, ServingMetrics carries the secondary
    modeled-seconds TTFT/TPOT/queue-delay, and elapsed_modeled equals
    the trace's modeled_s total."""
    cfg, params = setup
    eng, sched, res = _closed_loop(cfg, params, clock=None)
    m = res["metrics"]
    assert m.completed == 6
    assert m.elapsed_modeled == pytest.approx(
        sum(rec["modeled_s"] for rec in eng.trace), rel=1e-9)
    for pct in (m.ttft_modeled, m.tpot_modeled, m.queue_delay_modeled):
        assert np.isfinite(pct["p50"])
        assert pct["p50"] >= 0
    assert m.ttft_modeled["p50"] > 0
    assert m.throughput_modeled > 0
    d = m.to_dict()
    assert d["elapsed_modeled"] == m.elapsed_modeled
    assert d["ttft_modeled"] == m.ttft_modeled
    # the primary (iteration) metrics are untouched by the modeled clock
    assert m.elapsed == m.iterations


def test_modeled_primary_clock_drains(setup):
    """clock="modeled" advances scheduler.now by the engine's modeled
    seconds; the closed loop still drains and stamps finite latencies."""
    cfg, params = setup
    eng, sched, res = _closed_loop(cfg, params, clock="modeled")
    m = res["metrics"]
    assert m.completed == 6
    assert sched.modeled_now > 0
    assert np.isfinite(m.ttft["p50"])


def test_unknown_clock_string_rejected(setup):
    cfg, params = setup
    eng = Engine(params, cfg, ServeConfig(max_batch=2, max_ctx=32))
    with pytest.raises(ValueError, match="clock"):
        Scheduler(eng, SchedulerConfig(), clock="wall")
