"""Chunked prefill: kernel-level parity, engine equivalence, golden
determinism across kernels/schedule toggles (legacy + chunked paths)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.configs.base import SSMConfig
from repro.kernels import ops as kops
from repro.models import api, attention as attn_mod, mamba2 as ssm_mod
from repro.models import transformer
from repro.serving import Engine, ServeConfig


@pytest.fixture(scope="module")
def setup():
    cfg = reduced_config("granite-moe-1b-a400m").replace(dtype="float32")
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


# ---------------------------------------------------------------------------
# primitive parity: appending chunks == one full-sequence pass
# ---------------------------------------------------------------------------


def test_attention_append_matches_full():
    key = jax.random.PRNGKey(1)
    B, S, d, H, hd = 2, 12, 32, 4, 8
    params = attn_mod.attn_init(key, d, H, H, hd, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(2), (B, S, d), jnp.float32)
    full = attn_mod.attention(params, x, n_heads=H, n_kv=H, head_dim=hd,
                              rope_theta=10_000.0)
    cache = attn_mod.init_kv_cache(B, S + 4, H, hd, jnp.float32)
    cache_len = jnp.zeros((B,), jnp.int32)
    outs = []
    for k0, k1 in ((0, 5), (5, 8), (8, 12)):       # uneven chunks
        y, cache = attn_mod.attention_append(
            params, x[:, k0:k1], cache, cache_len, n_heads=H, n_kv=H,
            head_dim=hd, rope_theta=10_000.0)
        cache_len = cache_len + (k1 - k0)
        outs.append(y)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(outs, 1)),
                               np.asarray(full), rtol=2e-5, atol=2e-5)


def test_attention_append_masked_rows_untouched():
    key = jax.random.PRNGKey(3)
    B, K, d, H, hd = 3, 4, 16, 2, 8
    params = attn_mod.attn_init(key, d, H, H, hd, jnp.float32)
    cache = attn_mod.init_kv_cache(B, 16, H, hd, jnp.float32)
    cache = attn_mod.KVCache(cache.k + 7.0, cache.v - 3.0)
    x = jax.random.normal(jax.random.PRNGKey(4), (B, K, d), jnp.float32)
    mask = jnp.asarray([[True] * 4, [True, True, False, False],
                        [False] * 4])
    _, new = attn_mod.attention_append(params, x, cache,
                                       jnp.asarray([0, 2, 5], jnp.int32),
                                       n_heads=H, n_kv=H, head_dim=hd,
                                       rope_theta=10_000.0, token_mask=mask)
    # all-False row bit-untouched; other rows only at their chunk span
    assert np.array_equal(np.asarray(new.k[2]), np.asarray(cache.k[2]))
    assert np.array_equal(np.asarray(new.v[2]), np.asarray(cache.v[2]))
    assert np.array_equal(np.asarray(new.k[1, :2]), np.asarray(cache.k[1, :2]))
    assert np.array_equal(np.asarray(new.k[1, 4:]), np.asarray(cache.k[1, 4:]))
    assert not np.array_equal(np.asarray(new.k[1, 2:4]),
                              np.asarray(cache.k[1, 2:4]))


def test_mamba2_chunk_matches_sequential_oracle():
    ssm = SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=8, n_groups=1)
    d_model = 16
    params = ssm_mod.mamba2_init(jax.random.PRNGKey(5), d_model, ssm,
                                 jnp.float32)
    B, L = 2, 10
    x = jax.random.normal(jax.random.PRNGKey(6), (B, L, d_model), jnp.float32)
    full, full_state = ssm_mod.mamba2_prefill(params, x, ssm, d_model)
    state = ssm_mod.init_ssm_state(B, d_model, ssm, jnp.float32)
    outs = []
    for k0, k1 in ((0, 3), (3, 7), (7, 10)):
        y, state = ssm_mod.mamba2_chunk(params, x[:, k0:k1], state, ssm,
                                        d_model)
        outs.append(y)
    got = np.asarray(jnp.concatenate(outs, 1))
    np.testing.assert_allclose(got, np.asarray(full), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(state.conv),
                               np.asarray(full_state.conv), rtol=1e-5,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(state.ssd),
                               np.asarray(full_state.ssd), rtol=2e-4,
                               atol=2e-4)


def test_mamba2_chunk_masked_tail_is_noop():
    ssm = SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=8, n_groups=1)
    d_model = 16
    params = ssm_mod.mamba2_init(jax.random.PRNGKey(7), d_model, ssm,
                                 jnp.float32)
    B = 2
    x = jax.random.normal(jax.random.PRNGKey(8), (B, 6, d_model), jnp.float32)
    state0 = ssm_mod.init_ssm_state(B, d_model, ssm, jnp.float32)
    # 4 valid tokens + 2 garbage tail == exactly-4-token chunk
    _, s_mask = ssm_mod.mamba2_chunk(
        params, x, state0, ssm, d_model,
        token_mask=jnp.asarray([[True] * 4 + [False] * 2] * B))
    _, s_exact = ssm_mod.mamba2_chunk(params, x[:, :4], state0, ssm, d_model)
    np.testing.assert_allclose(np.asarray(s_mask.conv),
                               np.asarray(s_exact.conv), rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(s_mask.ssd),
                               np.asarray(s_exact.ssd), rtol=1e-6, atol=1e-6)
    # all-False rows: state bit-untouched
    _, s_noop = ssm_mod.mamba2_chunk(
        params, x, state0, ssm, d_model,
        token_mask=jnp.zeros((B, 6), bool))
    assert np.array_equal(np.asarray(s_noop.conv), np.asarray(state0.conv))
    assert np.array_equal(np.asarray(s_noop.ssd), np.asarray(state0.ssd))


def test_prefill_chunk_counts_layout(setup):
    """transformer.prefill_chunk returns per-layer expert counts at
    counts[L // p, L % p], summing to valid_tokens * top_k per MoE
    layer."""
    cfg, params = setup
    caches = transformer.init_caches(cfg, 2, 16)
    tokens = jnp.asarray([[1, 2, 3, 4], [5, 6, 0, 0]], jnp.int32)
    mask = jnp.asarray([[True] * 4, [True, True, False, False]])
    logits, caches, counts = transformer.prefill_chunk(
        params, tokens, caches, jnp.zeros((2,), jnp.int32), cfg,
        token_mask=mask)
    p, plan = transformer.period_plan(cfg)
    counts = np.asarray(counts)
    assert counts.shape[:2] == (cfg.num_layers // p, p)
    valid = 6
    for layer in range(cfg.num_layers):
        cnt = counts[layer // p, layer % p]
        if plan[layer % p][1] == "moe":
            assert cnt.sum() == valid * cfg.moe.top_k
        else:
            assert cnt.sum() == 0
    assert logits.shape[:2] == (2, 4)


# ---------------------------------------------------------------------------
# engine equivalence + golden determinism
# ---------------------------------------------------------------------------


PROMPTS = ((1, 2, 3, 4, 5, 6, 7, 8, 9, 10), (9, 8, 7))   # 10 > 2x chunk


def _run_engine(cfg, params, *, chunked, spec=None, chunk_tokens=4,
                max_new=5):
    eng = Engine(params, cfg, ServeConfig(max_batch=4, max_ctx=32,
                                          chunk_tokens=chunk_tokens,
                                          spec=spec))
    submit = eng.submit_chunked if chunked else eng.submit
    rids = [submit(list(pr), max_new=max_new) for pr in PROMPTS]
    outs = eng.run()
    return eng, [outs[r] for r in rids]


def test_chunked_prefill_matches_legacy_submit(setup):
    """Chunked admission emits the same tokens as the monolithic
    prefill for the same requests (greedy sampling; the prompt math is
    identical token-for-token, only its batching changes)."""
    cfg, params = setup
    _, legacy = _run_engine(cfg, params, chunked=False)
    _, chunked = _run_engine(cfg, params, chunked=True)
    assert legacy == chunked


def test_chunk_size_invariance(setup):
    """Token streams do not depend on the chunk size (1 == 3 == 16 ==
    whole prompt in one chunk)."""
    cfg, params = setup
    ref = None
    for ct in (1, 3, 16):
        _, outs = _run_engine(cfg, params, chunked=True, chunk_tokens=ct)
        if ref is None:
            ref = outs
        else:
            assert outs == ref, f"chunk_tokens={ct} diverged"


def test_prefill_admission_never_blocks_iteration(setup):
    """submit_chunked does no compute: the engine still iterates (and
    decodes other requests) while a long prompt is mid-prefill."""
    cfg, params = setup
    eng = Engine(params, cfg, ServeConfig(max_batch=4, max_ctx=32,
                                          chunk_tokens=2))
    r_long = eng.submit_chunked(list(range(1, 13)), max_new=3)   # 6 chunks
    assert eng.requests[r_long].generated == []                  # no prefill yet
    # a short request admitted later still decodes during the long prefill
    r_short = eng.submit_chunked([7, 7], max_new=4)
    seen_mixed = False
    for _ in range(40):
        ev = eng.step()
        rids = {r for r, _ in ev}
        if r_short in rids and eng.requests[r_long].phase == "prefill":
            seen_mixed = True
        if not eng.active():
            break
    assert seen_mixed, "short request should emit while long prefill runs"
    outs = {rid: r.generated for rid, r in eng.requests.items()}
    assert len(outs[r_long]) == 3 and len(outs[r_short]) == 4


@pytest.mark.parametrize("chunked", [False, True],
                         ids=["legacy-submit", "chunked-prefill"])
def test_golden_trace_determinism(setup, chunked):
    """Same seed + same submissions => bit-identical token streams and
    engine.trace across use_kernels(True/False) x schedule
    static|dynamic, for both admission paths (satellite: golden-trace
    determinism)."""
    cfg, params = setup

    def run(kernels, schedule):
        spec = {"strategy": "capacity", "schedule": schedule}
        with kops.use_kernels(kernels):
            eng, outs = _run_engine(cfg, params, chunked=chunked, spec=spec,
                                    max_new=4)
        trace = [(r["iter"], r["layer"], r["phase"], r["schedule"],
                  tuple(np.asarray(r["counts"]).tolist()))
                 for r in eng.trace]
        return outs, trace

    runs = {(k, s): run(k, s) for k in (False, True)
            for s in ("static", "dynamic")}
    outs0 = runs[(False, "static")][0]
    for key, (outs, _) in runs.items():
        assert outs == outs0, f"tokens diverged under {key}"
    # trace counts are kernel-invariant; static/dynamic only differ in
    # the recorded schedule tag + trajectory, not in counts
    t_static = runs[(False, "static")][1]
    assert runs[(True, "static")][1] == t_static
    t_dyn = [(i, l, p, "static", c)
             for (i, l, p, _s, c) in runs[(False, "dynamic")][1]]
    assert t_dyn == t_static
    assert runs[(True, "dynamic")][1] == runs[(False, "dynamic")][1]
    # and the runs are reproducible wholesale
    assert run(False, "static") == runs[(False, "static")]


def test_drop_free_serving_default(setup):
    """The engine defaults to drop-free capacity (C = T*k): a request's
    tokens cannot depend on who shares the batch."""
    cfg, params = setup
    eng = Engine(params, cfg, ServeConfig(max_batch=2, max_ctx=16))
    assert eng.cfg.moe.capacity_factor == float(cfg.moe.num_experts)
    eng2 = Engine(params, cfg, ServeConfig(max_batch=2, max_ctx=16,
                                           drop_free=False))
    assert eng2.cfg.moe.capacity_factor == cfg.moe.capacity_factor
