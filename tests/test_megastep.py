"""Fused mega-step engine: bit-identity vs the legacy eager loop,
recompile guard, host-sync budget, route-once structure."""
import jax
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.kernels.ops import use_kernels
from repro.models import api
from repro.serving import Engine, ServeConfig
from repro.serving import megastep


@pytest.fixture(scope="module")
def setup():
    cfg = reduced_config("granite-moe-1b-a400m").replace(dtype="float32")
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


PROMPTS = ((1, 2, 3, 4), (9, 8, 7))


def _run(cfg, params, *, fused, chunked, schedule, slack=0.0, nthr=None,
         kernels=False):
    spec = {"strategy": "capacity"}
    if schedule:
        spec["schedule"] = schedule
    with use_kernels(kernels):
        eng = Engine(params, cfg, ServeConfig(
            max_batch=4, max_ctx=48, fused=fused, chunk_tokens=4,
            buffering_slack=slack, theta_min=3, spec=spec))
        if nthr:
            eng.policy.n_threshold = nthr
        sub = eng.submit_chunked if chunked else eng.submit
        rids = [sub(list(p), max_new=6) for p in PROMPTS]
        outs = eng.run()
    return eng, [outs[r] for r in rids]


def _assert_same(e0, o0, e1, o1):
    """Tokens AND the full workload trace must match record for record
    (counts, order, EMA trajectory, modeled seconds — everything)."""
    assert o0 == o1
    assert len(e0.trace) == len(e1.trace)
    for a, b in zip(e0.trace, e1.trace):
        assert set(a) == set(b)
        for k in a:
            if isinstance(a[k], np.ndarray):
                assert (a[k] == b[k]).all(), k
            else:
                assert a[k] == b[k], k
    for k in ("deferrals", "dynamic_schedules", "tokens_emitted",
              "iterations", "expert_loads", "expert_loads_saved"):
        assert e0.stats[k] == e1.stats[k], k


@pytest.mark.parametrize("chunked", [False, True],
                         ids=["submit", "chunked"])
@pytest.mark.parametrize("schedule", [None, "dynamic"],
                         ids=["static", "dynamic"])
def test_fused_matches_legacy(setup, chunked, schedule):
    """Same seed => bit-identical tokens and trace between the fused
    jitted path and the legacy per-layer loop (the fused segments are
    built from the very same transformer.decode_* entry points)."""
    cfg, params = setup
    e0, o0 = _run(cfg, params, fused=False, chunked=chunked,
                  schedule=schedule)
    e1, o1 = _run(cfg, params, fused=True, chunked=chunked,
                  schedule=schedule)
    _assert_same(e0, o0, e1, o1)


@pytest.mark.parametrize("schedule", [None, "dynamic"],
                         ids=["static", "dynamic"])
def test_fused_matches_legacy_kernels(setup, schedule):
    """The identity must also hold with the Pallas kernel path enabled
    (the megastep cache keys on the ambient kernel flag)."""
    cfg, params = setup
    e0, o0 = _run(cfg, params, fused=False, chunked=True,
                  schedule=schedule, kernels=True)
    e1, o1 = _run(cfg, params, fused=True, chunked=True,
                  schedule=schedule, kernels=True)
    _assert_same(e0, o0, e1, o1)


def test_fused_matches_legacy_with_deferral(setup):
    """Algorithm-2 deferral churn (changing masks every iteration) on
    the fused path still reproduces the legacy loop exactly."""
    cfg, params = setup
    e0, o0 = _run(cfg, params, fused=False, chunked=True, schedule=None,
                  slack=0.5, nthr=2)
    e1, o1 = _run(cfg, params, fused=True, chunked=True, schedule=None,
                  slack=0.5, nthr=2)
    assert e1.stats["deferrals"] > 0
    _assert_same(e0, o0, e1, o1)


def test_steady_state_no_retrace_and_sync_budget(setup):
    """The tentpole's acceptance criterion: steady-state decode triggers
    ZERO retraces, and each iteration costs at most one host sync per
    MoE boundary plus the single batched logits fetch."""
    cfg, params = setup
    megastep._CACHE.clear()
    eng = Engine(params, cfg, ServeConfig(max_batch=4, max_ctx=48,
                                          chunk_tokens=4))
    for p in PROMPTS:
        eng.submit(list(p), max_new=12)
    eng.step()
    eng.step()                          # warmup: every segment traced
    ms = megastep.get_megastep(eng.cfg, eng.scfg)
    assert ms.traces > 0
    t0, s0 = ms.traces, eng.stats["host_syncs"]
    for _ in range(3):
        eng.step()
    nb = len(ms.boundaries)
    assert nb > 0
    assert ms.traces == t0, "steady-state decode retraced a segment"
    assert eng.stats["host_syncs"] - s0 == 3 * (nb + 1), \
        "more than one host sync per MoE boundary per iteration"


def test_fused_routes_each_moe_layer_once(setup, monkeypatch):
    """Structural route-once check for the fused path: tracing one
    decode iteration calls gating.route exactly once per MoE boundary
    (seg_first routes b0, each seg_mid routes its ending boundary,
    seg_last routes nothing) — the same Routing then drives deferral,
    the trace, and the expert execution."""
    from repro.core import gating
    cfg, params = setup
    megastep._CACHE.clear()
    calls = []
    real_route = gating.route

    def counting_route(*a, **kw):
        calls.append(1)
        return real_route(*a, **kw)

    eng = Engine(params, cfg, ServeConfig(max_batch=2, max_ctx=32))
    eng.submit([1, 2, 3], max_new=4)    # admission prefill routes too —
    monkeypatch.setattr(gating, "route", counting_route)  # count after
    eng.step()                          # traces seg_first/mid/last
    ms = megastep.get_megastep(eng.cfg, eng.scfg)
    assert len(ms.boundaries) > 0
    assert len(calls) == len(ms.boundaries), (len(calls), ms.boundaries)
    monkeypatch.undo()
    megastep._CACHE.clear()             # drop the counting-traced segments


def test_mesh_falls_back_to_legacy(setup, monkeypatch):
    """Under a distributed mesh the engine must take the eager path (a
    precomputed Routing only matches the single-process layout) even
    with fused=True — dispatch check only."""
    from repro.parallel import meshctx
    cfg, params = setup
    eng = Engine(params, cfg, ServeConfig(max_batch=2, max_ctx=32))
    eng.submit([1, 2, 3], max_new=2)
    called = {}
    eng._step_legacy = lambda: called.setdefault("legacy", True) and []
    eng._step_fused = lambda: called.setdefault("fused", True) and []
    monkeypatch.setattr(meshctx, "get_mesh", lambda: object())
    eng.step()
    assert called == {"legacy": True}
