"""Expert-trajectory scheduling (core.trajectory): Schedule construction,
EMA load feedback, traced-vs-host paired order, dynamic==static bit
parity through every single-device pipeline, load-aware cost model, and
the chiplet trajectory simulation where the dynamic schedule beats the
static plan on skewed gating."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MoEConfig
from repro.core import autotune, gating, strategy as strat, trajectory
from repro.core.policies import paired_load_order
from repro.core.strategy import ExecutionSpec
from repro.models import moe as moe_mod
from repro.sim import modes as sim_modes, workload
from repro.sim.hardware import PROTOTYPE_2X2, ModelSpec

D_MODEL = 16


def _setup(E=8, k=2, de=32, cf=4.0, act="swiglu"):
    moe = MoEConfig(num_experts=E, top_k=k, d_expert=de, capacity_factor=cf)
    params = moe_mod.moe_init(jax.random.PRNGKey(0), D_MODEL, moe, act,
                              jnp.float32)
    return moe, params


# ---------------------------------------------------------------------------
# Schedule construction
# ---------------------------------------------------------------------------


def test_schedule_static_ignores_counts():
    s = trajectory.build_schedule([5, 0, 3], policy="static")
    assert s.policy == "static" and s.order is None and s.load is None
    assert not s.dynamic


def test_schedule_dynamic_orders_and_pairs():
    counts = [10, 1, 5, 2]
    s = trajectory.build_schedule(counts, policy="dynamic")
    assert s.dynamic
    assert list(s.order) == paired_load_order(counts)
    assert s.pairs[0] == (0, 1)                 # hottest with coldest
    assert abs(sum(s.load) - 1.0) < 1e-12
    assert s.load[0] == pytest.approx(10 / 18)


def test_schedule_rejects_unknown_policy():
    with pytest.raises(ValueError):
        trajectory.Schedule(policy="jit")


def test_normalized_load_zero_counts():
    assert trajectory.normalized_load([0, 0, 0]) is None


def test_load_tracker_ema_tracks_drift():
    t = trajectory.LoadTracker(num_experts=3, decay=0.5)
    assert t.load_vector() is None
    assert t.schedule().order is None           # no data -> derive in-graph
    t.update([4, 0, 0])
    assert t.load_vector() == pytest.approx((1.0, 0.0, 0.0))
    # gating drifts to expert 2; EMA follows geometrically
    for _ in range(8):
        t.update([0, 0, 4])
    lv = t.load_vector()
    assert lv[2] > 0.95 and lv[0] < 0.05
    sched = t.schedule()
    assert sched.dynamic and sched.order[0] == 2


# ---------------------------------------------------------------------------
# traced order == host order
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("counts", [
    [5, 1, 9, 3, 2, 7, 4, 6],                  # all active, even E
    [5, 1, 9, 3, 2, 7, 4],                     # odd E
    [3, 3, 3, 3],                              # tied loads (stable sort)
    [7],                                       # single expert
])
def test_traced_order_matches_host(counts):
    got = list(np.asarray(trajectory.traced_order(jnp.asarray(counts))))
    assert got == paired_load_order(counts)


def test_traced_order_is_permutation_with_idle():
    counts = jnp.asarray([0, 5, 0, 2, 0, 0])
    got = sorted(np.asarray(trajectory.traced_order(counts)).tolist())
    assert got == list(range(6))


def test_resolve_order_static_is_none():
    assert trajectory.resolve_order(None, lambda: 1 / 0) is None
    s = trajectory.Schedule(policy="static")
    assert trajectory.resolve_order(s, lambda: 1 / 0) is None
    host = trajectory.build_schedule([3, 1, 2], policy="dynamic")
    order = trajectory.resolve_order(host, lambda: 1 / 0)
    assert list(np.asarray(order)) == list(host.order)


# ---------------------------------------------------------------------------
# dynamic == static, bit for bit (the virtualization argument)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("family", ["dense", "capacity", "fse_dp", "ep",
                                    "tp", "auto"])
def test_dynamic_schedule_bit_identical_single_device(family):
    moe, params = _setup()
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 10, D_MODEL),
                          jnp.float32)
    ys = moe_mod.moe_block(params, x, moe, "swiglu", spec=family)
    yd = moe_mod.moe_block(
        params, x, moe, "swiglu",
        spec=ExecutionSpec(strategy=family, schedule="dynamic"))
    assert np.array_equal(np.asarray(ys), np.asarray(yd)), family


def test_dynamic_schedule_bit_identical_sorted_dispatch():
    moe, params = _setup()
    x = jax.random.normal(jax.random.PRNGKey(4), (3, 8, D_MODEL), jnp.float32)
    ys = moe_mod.moe_block(params, x, moe, "swiglu", spec=ExecutionSpec(
        strategy="capacity", sorted_dispatch=True))
    yd = moe_mod.moe_block(params, x, moe, "swiglu", spec=ExecutionSpec(
        strategy="capacity", sorted_dispatch=True, schedule="dynamic"))
    assert np.array_equal(np.asarray(ys), np.asarray(yd))


def test_host_built_schedule_bit_identical():
    """An engine-style EMA schedule (host order) changes nothing either."""
    moe, params = _setup()
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 6, D_MODEL), jnp.float32)
    sched = trajectory.build_schedule([9, 1, 4, 2, 8, 3, 7, 5],
                                      policy="dynamic")
    ys = moe_mod.moe_block(params, x, moe, "swiglu", spec="capacity")
    yd = moe_mod.moe_block(params, x, moe, "swiglu", spec="capacity",
                           schedule=sched)
    assert np.array_equal(np.asarray(ys), np.asarray(yd))


def test_precomputed_routing_threads_through():
    """The pipeline's route stage accepts the engine's gate pass."""
    moe, params = _setup()
    x = jax.random.normal(jax.random.PRNGKey(6), (2, 5, D_MODEL), jnp.float32)
    routing = gating.route(params["router"], x.reshape(-1, D_MODEL),
                           top_k=moe.top_k)
    y0 = moe_mod.moe_block(params, x, moe, "swiglu", spec="capacity")
    y1 = moe_mod.moe_block(params, x, moe, "swiglu", spec="capacity",
                           routing=routing)
    assert np.array_equal(np.asarray(y0), np.asarray(y1))


# ---------------------------------------------------------------------------
# spec knob
# ---------------------------------------------------------------------------


def test_spec_schedule_roundtrip_and_validation():
    spec = ExecutionSpec(strategy="capacity", schedule="dynamic")
    assert ExecutionSpec.from_json(spec.to_json()) == spec
    assert "schedule" in spec.to_dict()
    assert ExecutionSpec(strategy="capacity").to_dict().get("schedule") is None
    with pytest.raises(ValueError):
        ExecutionSpec(strategy="capacity", schedule="eager")


# ---------------------------------------------------------------------------
# load-aware cost model
# ---------------------------------------------------------------------------


def test_cost_model_uniform_load_is_bit_identical():
    prof = autotune.HardwareProfile.from_chiplet()
    for mode in ("stream", "index", "slice"):
        c0 = autotune.mode_cost(mode, 4, 16, 512, 16, 512, 2, 1.25, 3, 4,
                                prof, 2)
        c1 = autotune.mode_cost(mode, 4, 16, 512, 16, 512, 2, 1.25, 3, 4,
                                prof, 2, load=None)
        assert c0 == c1
    e0 = autotune.ep_cost(4, 16, 512, 16, 512, 2, 1.25, 3, 4, prof)
    e1 = autotune.ep_cost(4, 16, 512, 16, 512, 2, 1.25, 3, 4, prof,
                          load=None)
    assert e0 == e1


def test_cost_model_skewed_load_cheaper_than_padded():
    """A skewed load vector activates fewer rows/experts than the padded
    shape-only model, so every family's predicted time drops."""
    prof = autotune.HardwareProfile.from_chiplet()
    E = 16
    zipf = np.arange(1, E + 1, dtype=np.float64) ** -1.5
    zipf /= zipf.sum()
    load = tuple(zipf)
    for mode in ("stream", "index", "slice"):
        c_pad = autotune.mode_cost(mode, 2, 16, 512, E, 512, 2, 1.25, 3, 4,
                                   prof, 2)["total_s"]
        c_load = autotune.mode_cost(mode, 2, 16, 512, E, 512, 2, 1.25, 3, 4,
                                    prof, 2, load=load)["total_s"]
        assert c_load < c_pad, mode
    moe = MoEConfig(num_experts=E, top_k=2, d_expert=512)
    plan = autotune.plan_moe(2, 16, 512, moe, "swiglu", 4, load=load)
    assert plan.predicted_s < autotune.plan_moe(2, 16, 512, moe, "swiglu",
                                                4).predicted_s
    fam = strat.plan_family(2, 16, 512, moe, "swiglu", 4, load=load)
    assert fam.family in strat.FAMILIES


def test_load_rows_caps_at_capacity():
    rows, active = autotune.load_rows(4, 10, 100.0, (0.97, 0.01, 0.01, 0.01))
    assert rows == pytest.approx(10 + 3 * 1.0)   # hot expert capacity-capped
    assert active == 4
    rows, active = autotune.load_rows(4, 10, 100.0, (1.0, 0.0, 0.0, 0.0))
    assert active == 1


# ---------------------------------------------------------------------------
# trajectory simulation: dynamic beats static on skewed gating
# ---------------------------------------------------------------------------

SKEW_SPEC = ModelSpec("skew", 2048, 1408, 64, 6, 3)


def _skewed_counts(seed, tokens, zipf_s=1.3):
    rng = np.random.default_rng(seed)
    p = workload.sample_expert_probs(SKEW_SPEC.num_experts, rng, zipf_s)
    return workload.route_tokens(SKEW_SPEC.num_experts, SKEW_SPEC.top_k,
                                 tokens, p, rng)


def test_dynamic_schedule_beats_static_on_skewed_gating():
    """Acceptance gate: over a Zipf-routed sweep, the count-built paired
    trajectory's simulated step time beats the shape-only static plan on
    a majority of points (here: all of them)."""
    wins = total = 0
    for tokens in (16, 32, 128, 512):
        for seed in range(5):
            t = sim_modes.schedule_step_times(PROTOTYPE_2X2, SKEW_SPEC,
                                              _skewed_counts(seed, tokens))
            wins += t["dynamic"] < t["static"]
            total += 1
    assert wins > total // 2, f"dynamic won only {wins}/{total}"
    assert wins >= total - 2          # in practice it wins ~everywhere


def test_static_trajectory_is_count_independent():
    """The static plan is shape-only: permuting the gating must not
    change its simulated step time (it pads every expert to capacity)."""
    c = _skewed_counts(0, 64)
    t1 = sim_modes.simulate_trajectory(PROTOTYPE_2X2, SKEW_SPEC, c,
                                       padded=True)
    t2 = sim_modes.simulate_trajectory(PROTOTYPE_2X2, SKEW_SPEC,
                                       np.random.default_rng(1).permutation(c),
                                       padded=True)
    assert t1 == pytest.approx(t2)


def test_simulate_mode_loads_cheaper_on_skew():
    """The SPMD-mode simulator referees the load-aware cost model: a
    skewed load vector lowers simulated latency vs the padded model."""
    E = SKEW_SPEC.num_experts
    counts = _skewed_counts(2, 64)
    loads = np.asarray(counts, np.float64) / counts.sum()
    for mode in ("stream", "index", "slice"):
        pad = sim_modes.simulate_mode(PROTOTYPE_2X2, SKEW_SPEC, mode,
                                      64, micro_slices=2).latency
        dyn = sim_modes.simulate_mode(PROTOTYPE_2X2, SKEW_SPEC, mode, 64,
                                      micro_slices=2,
                                      loads=tuple(loads)).latency
        assert dyn < pad, mode
    # uniform-None stays the padded model
    assert sim_modes.simulate_mode(PROTOTYPE_2X2, SKEW_SPEC, "stream", 64,
                                   loads=None).latency == \
        sim_modes.simulate_mode(PROTOTYPE_2X2, SKEW_SPEC, "stream",
                                64).latency
    assert E == 64
