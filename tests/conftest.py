import os
import sys

# smoke tests must see exactly ONE device — the 512-device flag is set
# only inside launch/dryrun.py subprocesses, never globally.
assert "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""), \
    "tests must run without the dry-run device-count flag"

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import subprocess


def run_distributed_script(name: str, timeout: int = 900) -> str:
    """Run tests/distributed_scripts/<name> in a subprocess with 8 fake
    devices (shard_map tests need >1 device; pytest itself must not)."""
    here = os.path.dirname(__file__)
    script = os.path.join(here, "distributed_scripts", name)
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.path.join(here, "..", "src"))
    out = subprocess.run([sys.executable, script], env=env, timeout=timeout,
                         capture_output=True, text=True)
    assert out.returncode == 0, f"{name} failed:\n{out.stdout}\n{out.stderr}"
    return out.stdout
