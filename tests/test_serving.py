"""Serving engine: deferral output-invariance, continuous batching,
slot lifecycle, trace export."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.models import api
from repro.serving import Engine, ServeConfig


@pytest.fixture(scope="module")
def setup():
    cfg = reduced_config("granite-moe-1b-a400m").replace(dtype="float32")
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _run(cfg, params, slack=0.0, n_threshold=None, prompts=((1, 2, 3, 4), (9, 8, 7))):
    eng = Engine(params, cfg, ServeConfig(max_batch=4, max_ctx=48,
                                          buffering_slack=slack, theta_min=3))
    if n_threshold:
        eng.policy.n_threshold = n_threshold
    rids = [eng.submit(list(p), max_new=6) for p in prompts]
    outs = eng.run()
    return eng, [outs[r] for r in rids]


def test_deferral_output_invariance(setup):
    """Algorithm 2 must never change generated tokens — only latency."""
    cfg, params = setup
    eng0, outs0 = _run(cfg, params, slack=0.0)
    eng1, outs1 = _run(cfg, params, slack=0.5, n_threshold=2)
    assert outs0 == outs1
    assert eng1.stats["deferrals"] > 0
    assert eng1.stats["iterations"] >= eng0.stats["iterations"]


def test_deferral_saves_expert_loads(setup):
    cfg, params = setup
    eng, _ = _run(cfg, params, slack=0.5, n_threshold=1)
    assert eng.stats["expert_loads_saved"] > 0


def test_continuous_batching_matches_sequential(setup):
    """Batched decoding == one-at-a-time decoding, token for token."""
    cfg, params = setup
    _, batched = _run(cfg, params, prompts=((1, 2, 3), (4, 5, 6, 7)))
    _, solo_a = _run(cfg, params, prompts=((1, 2, 3),))
    _, solo_b = _run(cfg, params, prompts=((4, 5, 6, 7),))
    assert batched[0] == solo_a[0]
    assert batched[1] == solo_b[0]


def test_slot_lifecycle(setup):
    cfg, params = setup
    eng = Engine(params, cfg, ServeConfig(max_batch=2, max_ctx=32))
    eng.submit([1, 2], max_new=3)
    eng.submit([3, 4], max_new=3)
    with pytest.raises(RuntimeError):
        eng.submit([5], max_new=2)
    eng.run()
    assert len(eng.free_slots) == 2          # slots reclaimed
    eng.submit([5, 6], max_new=2)            # reusable
    eng.run()


def test_trace_export(setup):
    cfg, params = setup
    eng, _ = _run(cfg, params)
    assert eng.trace, "per-layer expert counts exported for the simulator"
    rec = eng.trace[0]
    assert {"iter", "layer", "counts", "order"} <= set(rec)
    assert rec["counts"].sum() > 0
    assert sorted(rec["order"]) == list(range(cfg.moe.num_experts))


def test_mixed_length_prompts(setup):
    cfg, params = setup
    eng = Engine(params, cfg, ServeConfig(max_batch=4, max_ctx=48))
    r1 = eng.submit([1], max_new=4)
    r2 = eng.submit(list(range(1, 20)), max_new=4)
    outs = eng.run()
    assert len(outs[r1]) == 4 and len(outs[r2]) == 4
