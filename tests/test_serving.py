"""Serving engine: deferral output-invariance, continuous batching,
slot lifecycle, trace export."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.models import api
from repro.serving import Engine, ServeConfig


@pytest.fixture(scope="module")
def setup():
    cfg = reduced_config("granite-moe-1b-a400m").replace(dtype="float32")
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _run(cfg, params, slack=0.0, n_threshold=None, prompts=((1, 2, 3, 4), (9, 8, 7))):
    eng = Engine(params, cfg, ServeConfig(max_batch=4, max_ctx=48,
                                          buffering_slack=slack, theta_min=3))
    if n_threshold:
        eng.policy.n_threshold = n_threshold
    rids = [eng.submit(list(p), max_new=6) for p in prompts]
    outs = eng.run()
    return eng, [outs[r] for r in rids]


def test_deferral_output_invariance(setup):
    """Algorithm 2 must never change generated tokens — only latency."""
    cfg, params = setup
    eng0, outs0 = _run(cfg, params, slack=0.0)
    eng1, outs1 = _run(cfg, params, slack=0.5, n_threshold=2)
    assert outs0 == outs1
    assert eng1.stats["deferrals"] > 0
    assert eng1.stats["iterations"] >= eng0.stats["iterations"]


def test_deferral_saves_expert_loads(setup):
    cfg, params = setup
    eng, _ = _run(cfg, params, slack=0.5, n_threshold=1)
    assert eng.stats["expert_loads_saved"] > 0


def test_continuous_batching_matches_sequential(setup):
    """Batched decoding == one-at-a-time decoding, token for token."""
    cfg, params = setup
    _, batched = _run(cfg, params, prompts=((1, 2, 3), (4, 5, 6, 7)))
    _, solo_a = _run(cfg, params, prompts=((1, 2, 3),))
    _, solo_b = _run(cfg, params, prompts=((4, 5, 6, 7),))
    assert batched[0] == solo_a[0]
    assert batched[1] == solo_b[0]


def test_submit_rejects_oversized_requests(setup):
    """Satellite regression: len(prompt) + max_new > max_ctx raises a
    clear ValueError up front instead of silently truncating generation
    at the max_ctx - 1 boundary — on both admission paths."""
    cfg, params = setup
    eng = Engine(params, cfg, ServeConfig(max_batch=2, max_ctx=16))
    with pytest.raises(ValueError, match="max_ctx"):
        eng.submit(list(range(1, 13)), max_new=5)        # 12 + 5 > 16
    with pytest.raises(ValueError, match="max_ctx"):
        eng.submit_chunked(list(range(1, 13)), max_new=5)
    with pytest.raises(ValueError, match="empty"):
        eng.submit([], max_new=2)
    with pytest.raises(ValueError, match="max_new"):
        eng.submit([1, 2], max_new=0)
    assert len(eng.free_slots) == 2, "rejected requests hold no slot"
    # the boundary case fits (and is not truncated): 11 + 5 == 16
    rid = eng.submit(list(range(1, 12)), max_new=5)
    outs = eng.run()
    assert len(outs[rid]) == 5


def test_slot_recycling_constant_time(setup):
    """Satellite regression: slots recycle through a deque —
    admission pops left, completion appends right, both O(1)."""
    from collections import deque
    cfg, params = setup
    eng = Engine(params, cfg, ServeConfig(max_batch=3, max_ctx=32))
    assert isinstance(eng.free_slots, deque)
    r0 = eng.submit([1, 2], max_new=2)
    assert eng.requests[r0].slot == 0
    eng.run()
    assert list(eng.free_slots) == [1, 2, 0]             # recycled to tail
    r1 = eng.submit([3, 4], max_new=2)
    assert eng.requests[r1].slot == 1                    # FIFO slot reuse


def test_slot_lifecycle(setup):
    cfg, params = setup
    eng = Engine(params, cfg, ServeConfig(max_batch=2, max_ctx=32))
    eng.submit([1, 2], max_new=3)
    eng.submit([3, 4], max_new=3)
    with pytest.raises(RuntimeError):
        eng.submit([5], max_new=2)
    eng.run()
    assert len(eng.free_slots) == 2          # slots reclaimed
    eng.submit([5, 6], max_new=2)            # reusable
    eng.run()


def test_trace_export(setup):
    cfg, params = setup
    eng, _ = _run(cfg, params)
    assert eng.trace, "per-layer expert counts exported for the simulator"
    rec = eng.trace[0]
    assert {"iter", "layer", "counts", "order"} <= set(rec)
    assert rec["counts"].sum() > 0
    assert sorted(rec["order"]) == list(range(cfg.moe.num_experts))


def test_mixed_length_prompts(setup):
    cfg, params = setup
    eng = Engine(params, cfg, ServeConfig(max_batch=4, max_ctx=48))
    r1 = eng.submit([1], max_new=4)
    r2 = eng.submit(list(range(1, 20)), max_new=4)
    outs = eng.run()
    assert len(outs[r1]) == 4 and len(outs[r2]) == 4


# ---------------------------------------------------------------------------
# route-once pipeline + dynamic trajectory scheduling
# ---------------------------------------------------------------------------


def test_engine_routes_each_moe_layer_once(setup, monkeypatch):
    """The engine's gate pass IS the route stage: one gating.route call
    per MoE layer per iteration, threaded into both deferral and expert
    execution (no re-route inside moe_block).

    Pinned to the eager path: on the fused path gating.route only runs
    at trace time inside a cached compiled segment, so monkeypatch
    counting can't see it — tests/test_megastep.py has the fused
    structural counterpart."""
    from repro.core import gating
    cfg, params = setup
    eng = Engine(params, cfg, ServeConfig(max_batch=2, max_ctx=32,
                                          fused=False))
    eng.submit([1, 2, 3], max_new=4)

    calls = []
    real_route = gating.route

    def counting_route(*a, **kw):
        calls.append(1)
        return real_route(*a, **kw)

    monkeypatch.setattr(gating, "route", counting_route)
    eng.step()
    n_moe = sum(1 for _, f in (eng._layer_kind(l) for l in range(eng.L))
                if f == "moe")
    assert n_moe > 0
    assert len(calls) == n_moe, (len(calls), n_moe)


def test_dynamic_schedule_output_invariant(setup):
    """schedule=dynamic re-orders expert execution along the EMA
    trajectory but never changes emitted tokens (the virtualization
    argument, engine-level)."""
    from repro.core.strategy import ExecutionSpec
    cfg, params = setup

    def run(spec):
        eng = Engine(params, cfg, ServeConfig(max_batch=4, max_ctx=48,
                                              spec=spec))
        rids = [eng.submit(list(p), max_new=6) for p in ((1, 2, 3, 4),
                                                         (9, 8, 7))]
        outs = eng.run()
        return eng, [outs[r] for r in rids]

    e_s, o_s = run(ExecutionSpec(strategy="capacity"))
    e_d, o_d = run(ExecutionSpec(strategy="capacity", schedule="dynamic"))
    assert o_s == o_d
    assert e_d.stats["dynamic_schedules"] > 0
    assert e_s.stats["dynamic_schedules"] == 0
    # trace carries the executed trajectory under dynamic scheduling
    rec = e_d.trace[-1]
    assert rec["schedule"] == "dynamic"
    assert sorted(rec["trajectory"]) == list(range(cfg.moe.num_experts))
    assert e_s.trace[-1]["schedule"] == "static"
    # EMA trackers observed every MoE layer
    assert e_d.load_trackers and all(
        t.steps > 0 for t in e_d.load_trackers.values())


def test_trace_counts_use_gating_helper(setup):
    """Engine counts == gating.expert_token_counts over the active
    slots (the hand-rolled numpy loop is gone)."""
    import jax.numpy as jnp
    from repro.core import gating
    cfg, params = setup
    eng, _ = _run(cfg, params)
    rec = eng.trace[0]
    assert rec["counts"].dtype == np.int64
    assert rec["counts"].sum() > 0
    # a masked row contributes nothing
    x2d = jax.random.normal(jax.random.PRNGKey(0), (4, cfg.d_model))
    routing = gating.route(
        jax.tree.map(lambda a: a[0], params["periods"][0])["moe"]["router"],
        x2d, top_k=cfg.moe.top_k)
    m = jnp.asarray([True, False, False, False])
    assert int(gating.expert_token_counts(routing, m).sum()) == cfg.moe.top_k


def test_serveconfig_deprecated_aliases_warn_once():
    """Satellite: moe_impl / autotune aliases emit a one-shot
    DeprecationWarning and still merge into the spec."""
    import warnings as _w
    from repro.serving import engine as engine_mod
    engine_mod._ALIAS_WARNED.clear()
    with pytest.warns(DeprecationWarning, match="moe_impl"):
        sc = ServeConfig(moe_impl="dense")
    assert sc.spec.strategy == "dense"
    with pytest.warns(DeprecationWarning, match="autotune"):
        sc = ServeConfig(autotune="off")
    assert sc.spec.autotune == "off"
    with _w.catch_warnings():
        _w.simplefilter("error")               # second use is silent
        ServeConfig(moe_impl="dense", autotune="off")
    # spec-based configuration never warns
    with _w.catch_warnings():
        _w.simplefilter("error")
        ServeConfig(spec="capacity")
