"""Docs health: relative-link integrity and doctested examples.

Two guarantees, both cheap enough for the fast CI job:

* every relative markdown link in ``docs/*.md`` and ``README.md``
  resolves to a real file in the repo (external http(s) links and pure
  anchors are skipped), and the README links all four docs pages;
* every fenced ```python block in ``docs/execution-spec.md`` runs as a
  doctest, with the repo root as cwd so the
  ``ExecutionSpec.load("examples/moe-spec.json")`` example resolves.
"""
from __future__ import annotations

import doctest
import os
import re
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent
DOCS = REPO / "docs"

DOC_PAGES = [
    "architecture.md",
    "trace-format.md",
    "statepool.md",
    "execution-spec.md",
    "benchmarks.md",
    "quantization.md",
]

# [text](target) — excludes images (![...]) via the lookbehind; target is
# taken up to the first closing paren (no nested-paren targets in our docs).
_LINK_RE = re.compile(r"(?<!\!)\[[^\]]+\]\(([^)#][^)]*)\)")


def _markdown_files():
    files = [REPO / "README.md"]
    files.extend(sorted(DOCS.glob("*.md")))
    return files


def _relative_links(md: Path):
    """Yield (link, resolved_target) for each relative link in *md*."""
    for link in _LINK_RE.findall(md.read_text()):
        if link.startswith(("http://", "https://", "mailto:")):
            continue
        target = link.split("#", 1)[0]
        if not target:
            continue
        yield link, (md.parent / target).resolve()


def test_docs_pages_exist():
    for page in DOC_PAGES:
        assert (DOCS / page).is_file(), f"missing docs page: docs/{page}"


@pytest.mark.parametrize("md", _markdown_files(), ids=lambda p: p.name)
def test_relative_links_resolve(md):
    broken = [link for link, path in _relative_links(md) if not path.exists()]
    assert not broken, f"{md.relative_to(REPO)} has broken links: {broken}"


def test_readme_links_every_docs_page():
    linked = {path for _, path in _relative_links(REPO / "README.md")}
    missing = [p for p in DOC_PAGES if (DOCS / p).resolve() not in linked]
    assert not missing, f"README.md does not link docs pages: {missing}"


def test_docs_cross_link_each_other_and_readme():
    readme = (REPO / "README.md").resolve()
    for page in DOC_PAGES:
        linked = {path for _, path in _relative_links(DOCS / page)}
        assert readme in linked, f"docs/{page} does not link back to README"


_FENCE_RE = re.compile(r"```python\n(.*?)```", re.DOTALL)


def _doctest_blocks(md: Path):
    return _FENCE_RE.findall(md.read_text())


def test_execution_spec_examples_are_doctests():
    """Run every fenced ```python block of docs/execution-spec.md as a
    doctest, sharing one namespace across blocks (later blocks reuse
    ``spec``/imports from earlier ones), with cwd = repo root so the
    ``examples/moe-spec.json`` load resolves."""
    blocks = _doctest_blocks(DOCS / "execution-spec.md")
    assert blocks, "docs/execution-spec.md has no fenced python examples"

    parser = doctest.DocTestParser()
    runner = doctest.DocTestRunner(
        optionflags=doctest.ELLIPSIS | doctest.IGNORE_EXCEPTION_DETAIL
    )
    globs: dict = {}
    cwd = os.getcwd()
    os.chdir(REPO)
    try:
        for i, block in enumerate(blocks):
            test = parser.get_doctest(
                block, globs, f"execution-spec.md[block {i}]", None, None
            )
            runner.run(test, clear_globs=False)
            globs = test.globs  # carry state forward
    finally:
        os.chdir(cwd)
    assert runner.failures == 0, (
        f"{runner.failures} doctest failure(s) in docs/execution-spec.md "
        "(run pytest -s to see the diffs)"
    )
