"""Whisper enc-dec: decode chain matches teacher forcing."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import reduced_config
from repro.models import whisper


def test_decode_matches_teacher_forcing():
    cfg = reduced_config("whisper-base").replace(dtype="float32")
    params = whisper.init_encdec(jax.random.PRNGKey(0), cfg)
    B, F, S = 2, 12, 6
    frames = jax.random.normal(jax.random.PRNGKey(1), (B, F, cfg.d_model))
    tokens = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab_size)

    memory = whisper.encode(params, frames, cfg)
    logits_tf = whisper.decode_train(params, tokens, memory, cfg)

    caches = whisper.init_decode_caches(params, memory, cfg, B, max_seq=16)
    outs = []
    for t in range(S):
        lg, caches = whisper.decode_step(params, tokens[:, t:t + 1], caches,
                                         jnp.full((B,), t, jnp.int32), cfg)
        outs.append(lg)
    logits_dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(logits_dec), np.asarray(logits_tf),
                               rtol=2e-3, atol=2e-3)


def test_encoder_bidirectional():
    """Encoder output at position 0 depends on later frames (non-causal)."""
    cfg = reduced_config("whisper-base").replace(dtype="float32")
    params = whisper.init_encdec(jax.random.PRNGKey(0), cfg)
    frames = jax.random.normal(jax.random.PRNGKey(1), (1, 8, cfg.d_model))
    m1 = whisper.encode(params, frames, cfg)
    m2 = whisper.encode(params, frames.at[0, 7].set(5.0), cfg)
    assert not np.allclose(np.asarray(m1[0, 0]), np.asarray(m2[0, 0]), atol=1e-6)
