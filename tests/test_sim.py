"""Chiplet simulator invariants: work conservation, buffer accounting,
strategy orderings matching the paper's claims, rules behaviour."""
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.sim import (PROTOTYPE_2X2, PAPER_SPECS, ChipletSim, scaled,
                       iteration_workloads, simulate_layer)

HW = PROTOTYPE_2X2
SPEC = PAPER_SPECS["qwen3-a3b"]


def _wl(tokens=64, seed=0, spec=SPEC):
    return iteration_workloads(spec, tokens_per_iter=tokens,
                               num_chiplets=HW.num_chiplets, seed=seed)[0]


def test_work_conservation():
    """Total busy compute time == exact sum of per-(chip, expert) work."""
    wl = _wl()
    r = simulate_layer(HW, SPEC, wl, "fse_dp_paired")
    expected = wl.counts.sum() * SPEC.expert_flops_per_token() / HW.tops
    np.testing.assert_allclose(r.busy_time.sum(), expected, rtol=1e-6)


def test_ddr_bytes_exact():
    """Every active expert's weights are fetched exactly once."""
    wl = _wl()
    active = int((wl.expert_totals > 0).sum())
    for strat in ("fse_dp", "fse_dp_paired", "ep", "hydra"):
        r = simulate_layer(HW, SPEC, wl, strat)
        np.testing.assert_allclose(r.ddr_bytes, active * SPEC.expert_bytes,
                                   rtol=1e-9, err_msg=strat)


def test_fse_dp_memory_beats_ep():
    """The paper's Fig. 12: FSE-DP package memory ≲ 1/3 of EP's."""
    for name, spec in PAPER_SPECS.items():
        wl = _wl(tokens=64, spec=spec)
        m_fse = simulate_layer(HW, spec, wl, "fse_dp_paired").peak_buffer_bytes
        m_ep = simulate_layer(HW, spec, wl, "ep").peak_buffer_bytes
        assert m_fse < m_ep / 2.0, (name, m_fse, m_ep)


def test_fse_dp_latency_beats_ep_low_batch():
    """Fig. 9: FSE-DP speedup over EP across the paper's models (>=1.1x
    on the 64-token cell, averaged over seeds)."""
    for name, spec in PAPER_SPECS.items():
        speedups = []
        for seed in range(3):
            wl = _wl(tokens=64, seed=seed, spec=spec)
            l_fse = simulate_layer(HW, spec, wl, "fse_dp_paired").latency
            l_ep = simulate_layer(HW, spec, wl, "ep").latency
            speedups.append(l_ep / l_fse)
        assert np.mean(speedups) > 1.1, (name, speedups)


def test_naive_fsedp_worse_than_fine_grained():
    """Ablation A1 vs A2 (Fig. 15): micro-slice flow beats phase-sync."""
    wl = _wl(tokens=256)
    a1 = simulate_layer(HW, SPEC, wl, "fse_dp_naive").latency
    a2 = simulate_layer(HW, SPEC, wl, "fse_dp").latency
    assert a2 < a1


def test_trajectories_visit_token_chiplets_only():
    sim = ChipletSim(HW, SPEC, _wl(), strategy="fse_dp")
    for e in range(SPEC.num_experts):
        traj = sim._trajectory(e)
        for c in traj:
            assert sim.wl.counts[c, e] > 0
        for c in set(range(HW.num_chiplets)) - set(traj):
            assert sim.wl.counts[c, e] == 0


def test_utilization_bounds():
    for strat in ("ep", "hydra", "fse_dp", "fse_dp_paired", "fse_dp_rule5"):
        r = simulate_layer(HW, SPEC, _wl(), strat)
        assert 0.0 <= r.utilization <= 1.0
        assert r.latency > 0


def test_d2d_bytes_zero_for_ep():
    """EP moves tokens (charged in compute chain), never weights."""
    r = simulate_layer(HW, SPEC, _wl(), "ep")
    assert r.d2d_bytes == 0.0


def test_fse_dp_streams_weights():
    """each micro-slice traverses its trajectory: d2d bytes ≈
    Σ_e expert_bytes · (|traj_e| - 1)."""
    wl = _wl()
    r = simulate_layer(HW, SPEC, wl, "fse_dp")
    # exact: every micro-slice makes |traj|-1 hops
    total = 0.0
    for e in range(SPEC.num_experts):
        traj = [c for c in range(HW.num_chiplets) if wl.counts[c, e] > 0]
        if traj:
            total += SPEC.expert_bytes * (len(traj) - 1)
    np.testing.assert_allclose(r.d2d_bytes, total, rtol=1e-6)


def test_scalability_fse_dp_degrades_less():
    """Fig. 18: utilization drop 2x2 -> 4x4 is smaller for FSE-DP than EP."""
    spec = PAPER_SPECS["qwen3-a3b"]
    util = {}
    for strat in ("ep", "fse_dp_paired"):
        us = {}
        for rows in (2, 4):
            hw = scaled(rows, rows)
            wl = iteration_workloads(spec, tokens_per_iter=256,
                                     num_chiplets=hw.num_chiplets, seed=0)[0]
            us[rows] = simulate_layer(hw, spec, wl, strat).utilization
        util[strat] = us[4] / max(us[2], 1e-9)
    assert util["fse_dp_paired"] > util["ep"]


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 1000), st.sampled_from([16, 64, 256]))
def test_no_deadlock_property(seed, tokens):
    wl = iteration_workloads(SPEC, tokens_per_iter=tokens,
                             num_chiplets=HW.num_chiplets, seed=seed)[0]
    r = simulate_layer(HW, SPEC, wl, "fse_dp_paired")
    assert np.isfinite(r.latency)


def test_scaled_ddr_channels_track_longest_edge():
    """§VI-E scaling regression: DDR channel count (and with it
    aggregate DDR bandwidth) scales with the array's longest edge, so
    transposed arrays are symmetric and non-square arrays are not stuck
    at the row count."""
    from repro.sim import with_ndp

    def ch(hw):
        return hw.ddr_channels

    assert ch(scaled(2, 2)) == ch(PROTOTYPE_2X2) == 4
    assert ch(scaled(2, 4)) == ch(scaled(4, 2)) == 8    # was 4 vs 8
    assert ch(scaled(3, 3)) == 6
    assert scaled(2, 4).ddr_total == scaled(4, 2).ddr_total
    # the near-memory tier's local bandwidth scales with the same ratio
    a, b = with_ndp(scaled(2, 4)), with_ndp(scaled(4, 2))
    assert a.ndp.gbps == b.ndp.gbps > with_ndp(scaled(2, 2)).ndp.gbps


def test_expert_bytes_follow_hardware_dtype():
    """ModelSpec.expert_bytes no longer hardcodes bf16: with no
    explicit weight dtype the per-expert footprint follows the
    hardware's bytes_per_param."""
    from dataclasses import replace

    from repro.sim import ModelSpec, spec_from_config
    from repro.sim.modes import simulate_mode
    from repro.configs import reduced_config

    hw4 = replace(PROTOTYPE_2X2, bytes_per_param=4)
    spec = ModelSpec("s", 256, 512, 8, 2)     # bytes_per_param=None
    assert spec.expert_bytes_on(hw4) == 2 * spec.expert_bytes_on(PROTOTYPE_2X2)
    assert spec.expert_bytes == spec.expert_bytes_on(PROTOTYPE_2X2)
    # explicit dtype still pins the footprint regardless of hardware
    pinned = replace(spec, bytes_per_param=2)
    assert pinned.expert_bytes_on(hw4) == spec.expert_bytes_on(PROTOTYPE_2X2)
    # spec_from_config threads the hardware default through
    cfg = reduced_config("granite-moe-1b-a400m")
    s4 = spec_from_config(cfg, hw=hw4)
    s2 = spec_from_config(cfg, hw=PROTOTYPE_2X2)
    assert s4.bytes_per_param == 4 and s2.bytes_per_param == 2
    # and the referee's DDR traffic doubles with the wider dtype
    t2 = simulate_mode(PROTOTYPE_2X2, spec, "stream", 4)
    t4 = simulate_mode(hw4, spec, "stream", 4)
    assert t4.ddr_bytes == 2 * t2.ddr_bytes
