"""Scheduler conformance: bounded queue, FIFO fairness, starvation
bound, slot-recycling complexity, engine<->simulator load agreement."""
from collections import deque

import jax
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.models import api
from repro.serving import (Engine, Scheduler, SchedulerConfig, ServeConfig)
from repro.sim import workload as sim_workload


@pytest.fixture(scope="module")
def setup():
    cfg = reduced_config("granite-moe-1b-a400m").replace(dtype="float32")
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _sched(cfg, params, *, max_batch=2, policy="fcfs", capacity=4,
           starvation_limit=8, chunk_tokens=4):
    eng = Engine(params, cfg, ServeConfig(max_batch=max_batch, max_ctx=32,
                                          chunk_tokens=chunk_tokens))
    return Scheduler(eng, SchedulerConfig(queue_capacity=capacity,
                                          policy=policy,
                                          starvation_limit=starvation_limit))


# ---------------------------------------------------------------------------
# queue behavior (no engine compute needed until step())
# ---------------------------------------------------------------------------


def test_queue_never_exceeds_bound(setup):
    cfg, params = setup
    s = _sched(cfg, params, capacity=3)
    rids = [s.offer([1, 2], 2) for _ in range(6)]
    assert sum(r is not None for r in rids) == 3
    assert rids[3:] == [None, None, None]
    assert s.queue_depth() == 3 and s.rejected == 3
    # backpressure clears as the queue drains into slots
    s.admit_ready()
    assert s.queue_depth() == 1                      # 2 slots filled
    assert s.offer([3, 4], 2) is not None


def test_fifo_order_preserved_under_equal_lengths(setup):
    cfg, params = setup
    s = _sched(cfg, params, max_batch=1, capacity=16)
    rids = [s.offer([1, 2, 3], 2) for _ in range(5)]
    admitted = []
    for _ in range(60):
        admitted += s.admit_ready()
        if len(admitted) == 5:
            break
        s.step()
    assert admitted == rids, "equal-length requests must admit in FIFO order"


def test_spf_prefers_short_prompts(setup):
    cfg, params = setup
    s = _sched(cfg, params, max_batch=1, policy="spf", capacity=16,
               starvation_limit=1000)
    r_long = s.offer(list(range(1, 13)), 2)
    r_short = s.offer([5, 5], 2)
    first = []
    while not first:
        first = s.admit_ready()
        s.step()
    # both requests were queued before any admission: spf must admit
    # the short one into the single slot first, despite arrival order
    assert first == [r_short] and r_long is not None


def test_no_starvation_under_spf_aging(setup):
    """A long prompt at the queue head is admitted within
    starvation_limit iterations once slots free, even while shorter
    prompts keep arriving (the aging guard)."""
    cfg, params = setup
    lim = 6
    s = _sched(cfg, params, max_batch=1, policy="spf", capacity=64,
               starvation_limit=lim)
    r_long = s.offer(list(range(1, 15)), 2)          # queue head, longest
    admitted_at = None
    for it in range(120):
        s.offer([7, 8], 2)                           # fresh short each iter
        s.step()
        t = s.tickets[r_long]
        if t.admitted_iter is not None:
            admitted_at = t
            break
    assert admitted_at is not None, "long request starved"
    # admitted at the first slot-free event after the aging bound trips:
    # bounded by starvation_limit + one short-request service time
    waited = admitted_at.admitted_iter - admitted_at.arrival_iter
    assert waited <= lim + 8, f"waited {waited} > aging bound {lim}+8"


def test_slot_recycling_is_o1(setup):
    """Satellite regression: free-slot recycling must be a deque
    (popleft/append are O(1); the old list.pop(0) was O(max_batch))."""
    cfg, params = setup
    eng = Engine(params, cfg, ServeConfig(max_batch=3, max_ctx=16))
    assert isinstance(eng.free_slots, deque)
    a = eng.free_slots.popleft()
    eng.free_slots.append(a)
    assert list(eng.free_slots) == [1, 2, 0]         # FIFO slot rotation


def test_offer_validates_at_the_door(setup):
    cfg, params = setup
    s = _sched(cfg, params)
    with pytest.raises(ValueError, match="max_ctx"):
        s.offer(list(range(40)), 10)                 # 40 + 10 > 32
    assert s.queue_depth() == 0


# ---------------------------------------------------------------------------
# metrics + engine<->simulator conformance
# ---------------------------------------------------------------------------


def test_metrics_lifecycle(setup):
    cfg, params = setup
    s = _sched(cfg, params, max_batch=2, capacity=8)
    s.offer([1, 2, 3, 4, 5], 3)
    s.offer([9, 8], 2)
    s.drain()
    m = s.metrics()
    assert m.completed == 2 and m.rejected == 0
    assert m.tokens_emitted == 5
    for pct in (m.ttft, m.queue_delay):
        assert pct["p50"] <= pct["p95"] <= pct["p99"]
    # queue delay cannot exceed TTFT (admission precedes the first token)
    assert m.queue_delay["p50"] <= m.ttft["p50"]
    assert m.throughput > 0


def test_engine_vs_simulator_load_agreement(setup):
    """Conformance: replaying the engine's workload trace through
    sim.workload reproduces the engine's per-expert loads exactly, and
    the replayed workloads run through the chiplet simulator."""
    from repro.sim.engine import simulate_layer
    from repro.sim.hardware import PROTOTYPE_2X2, spec_from_config

    cfg, params = setup
    s = _sched(cfg, params, max_batch=2, chunk_tokens=3)
    s.offer([1, 2, 3, 4, 5, 6, 7], 3)
    s.offer([9, 8, 7], 2)
    s.drain()
    trace = s.engine.trace
    assert trace and {"prefill", "decode"} == {r["phase"] for r in trace}

    P = PROTOTYPE_2X2.num_chiplets
    replayed = sim_workload.workloads_from_trace(trace, P)
    assert len(replayed) == len(trace)
    # exact per-record agreement: chiplet-striped counts sum back
    for rec, (it, layer, wl) in zip(trace, replayed):
        assert (it, layer) == (rec["iter"], rec["layer"])
        np.testing.assert_array_equal(wl.expert_totals,
                                      np.asarray(rec["counts"]))
    # aggregate per-layer agreement
    totals = sim_workload.trace_expert_totals(trace)
    agg = {}
    for _, layer, wl in replayed:
        agg[layer] = agg.get(layer, 0) + wl.expert_totals
    for layer, t in totals.items():
        np.testing.assert_array_equal(agg[layer], t)
        assert t.sum() > 0
    # the replayed workload drives the cycle-level simulator
    spec = spec_from_config(s.engine.cfg)
    busiest = max((wl for _, _, wl in replayed),
                  key=lambda w: w.expert_totals.sum())
    res = simulate_layer(PROTOTYPE_2X2, spec, busiest, "fse_dp_paired")
    assert res.latency > 0 and 0 <= res.utilization <= 1
    np.testing.assert_array_equal(
        sorted(np.nonzero(busiest.expert_totals)[0]),
        sorted(set(range(spec.num_experts))
               - set(res.dropped_experts)
               - set(np.where(busiest.expert_totals == 0)[0])))


def test_streaming_emission_callback(setup):
    cfg, params = setup
    eng = Engine(params, cfg, ServeConfig(max_batch=2, max_ctx=32,
                                          chunk_tokens=4))
    seen = []
    s = Scheduler(eng, SchedulerConfig(queue_capacity=8),
                  on_token=lambda rid, tok: seen.append((rid, tok)))
    rid = s.offer([1, 2, 3], 3)
    s.drain()
    assert [t for r, t in seen if r == rid] == s.outputs()[rid]
    assert len(seen) == 3
