"""Property-style ChipletSim invariants over randomized workloads.

Three paper-level conservation laws of the discrete-event simulator:

* **micro-slice conservation** — every routed token is computed exactly
  once: per-chiplet busy time equals sum_e counts[c,e] * flops / TOPS
  (each micro-slice visits every station of its trajectory once);
* **no D2D before load** — a micro-slice may not be forwarded over the
  D2D ring before its DDR load completed (Rule 1 forwards *with* the
  first compute, which itself waits for load_done);
* **bounded utilization** — aggregate utilization and the binned
  ``util_series`` curve live in [0, 1].

Runs through the ``tests/_hyp.py`` shim: with hypothesis installed the
``@given`` cases fuzz seeds; without it (this env) the same invariant
checker still executes over a deterministic seed sweep.
"""
import numpy as np
import pytest

from _hyp import HAVE_HYPOTHESIS, given, settings, st

from repro.sim.engine import ChipletSim, simulate_layer
from repro.sim.hardware import PROTOTYPE_2X2, ModelSpec
from repro.sim.workload import make_layer_workload, make_requests

SPEC = ModelSpec(name="prop", d_model=256, d_expert=512, num_experts=16,
                 top_k=2)


def _workload(seed: int, tokens: int = 48):
    reqs = make_requests(tokens, PROTOTYPE_2X2.num_chiplets, seed)
    return make_layer_workload(SPEC, reqs, PROTOTYPE_2X2.num_chiplets,
                               layer_idx=0, seed=seed)


def _parse(timeline):
    """timeline -> {uid: {"load": (t, dur), "xfers": [t...], "computes": [t...]}}"""
    by_uid = {}
    for t, _chip, kind, dur in timeline:
        kind = str(kind)
        if ":u" not in kind:
            continue
        uid = int(kind.rsplit(":u", 1)[1])
        d = by_uid.setdefault(uid, {"load": None, "xfers": [], "computes": []})
        if kind.startswith("load:"):
            d["load"] = (t, dur)
        elif kind.startswith("xfer:"):
            d["xfers"].append(t)
        elif kind.startswith("compute:"):
            d["computes"].append(t)
    return by_uid


def check_invariants(seed: int, strategy: str = "fse_dp_paired"):
    wl = _workload(seed)
    res = simulate_layer(PROTOTYPE_2X2, SPEC, wl, strategy,
                         record_timeline=True)

    # bounded utilization
    assert 0.0 <= res.utilization <= 1.0 + 1e-9, res.utilization
    series = res.util_series(bins=16)
    assert np.all(series >= -1e-9) and np.all(series <= 1.0 + 1e-9), series
    assert res.latency > 0.0

    # micro-slice conservation: every routed token computed exactly once
    expected = wl.counts.astype(np.float64) \
        * SPEC.expert_flops_per_token() / PROTOTYPE_2X2.tops
    np.testing.assert_allclose(res.busy_time, expected.sum(axis=1),
                               rtol=1e-9, atol=1e-15)
    assert not res.dropped_experts

    # no D2D transfer (and no compute) before the slice's load completed
    by_uid = _parse(res.timeline)
    assert by_uid, "timeline carries per-slice uids"
    loaded = [d for d in by_uid.values() if d["load"] is not None]
    assert loaded, "every run DDR-loads at least one micro-slice"
    for d in loaded:
        t_done = d["load"][0] + d["load"][1]
        for t in d["xfers"]:
            assert t >= t_done - 1e-12, (t, t_done)
        for t in d["computes"]:
            assert t >= t_done - 1e-12, (t, t_done)


@pytest.mark.parametrize("seed", range(8))
def test_invariants_seed_sweep(seed):
    check_invariants(seed)


@pytest.mark.parametrize("strategy", ["fse_dp", "fse_dp_rule5"])
def test_invariants_other_orders(strategy):
    check_invariants(0, strategy=strategy)


@given(st.integers(min_value=0, max_value=10_000))
@settings(max_examples=25, deadline=None)
def test_invariants_property(seed):
    check_invariants(seed)


def test_util_series_matches_aggregate():
    """Integral of the binned curve equals the aggregate utilization."""
    wl = _workload(3)
    res = simulate_layer(PROTOTYPE_2X2, SPEC, wl, "fse_dp_paired",
                         record_timeline=True)
    series = res.util_series(bins=64)
    assert abs(float(series.mean()) - res.utilization) < 1e-6


def test_whole_expert_strategies_bounded():
    """EP / hydra share the event engine; utilization stays bounded.
    For EP, busy time is owner-resident compute plus the token-I/O term
    charged to the owner's compute chain, so it lower-bounds the
    owner-count compute exactly (owner of e is e % P)."""
    wl = _workload(1)
    for strategy in ("ep", "hydra"):
        res = simulate_layer(PROTOTYPE_2X2, SPEC, wl, strategy,
                             record_timeline=True)
        assert 0.0 <= res.utilization <= 1.0 + 1e-9
        assert res.latency > 0.0
    P = PROTOTYPE_2X2.num_chiplets
    res = simulate_layer(PROTOTYPE_2X2, SPEC, wl, "ep")
    owner_counts = np.array([wl.counts[e % P, e]
                             for e in range(SPEC.num_experts)], np.float64)
    lower = owner_counts.sum() * SPEC.expert_flops_per_token() \
        / PROTOTYPE_2X2.tops
    assert res.busy_time.sum() >= lower - 1e-12


def test_hyp_shim_mode():
    """Document which mode the property cases ran in (skip-shim or real
    hypothesis) so a CI log shows the coverage actually exercised."""
    assert HAVE_HYPOTHESIS in (True, False)
