"""End-to-end behaviour of the whole system (paper claims on CPU scale):
train a small MoE -> serve it with token buffering -> replay its expert
trace in the chiplet simulator and check the paper's orderings."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.data import DataConfig
from repro.models import api
from repro.serving import Engine, ServeConfig
from repro.sim import PROTOTYPE_2X2, LayerWorkload, simulate_layer, spec_from_config
from repro.training import TrainConfig, train


@pytest.mark.slow
def test_train_serve_simulate_pipeline():
    cfg = reduced_config("granite-moe-1b-a400m").replace(dtype="float32")
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8, seed=3)

    # 1) train briefly — loss must improve
    res = train(cfg, dcfg, TrainConfig(total_steps=30, warmup=5, lr=3e-3,
                                       log_every=29), seed=0)
    assert res.losses[-1][1] < res.losses[0][1]

    # 2) serve the trained model with token buffering
    eng = Engine(res.params, cfg, ServeConfig(max_batch=4, max_ctx=48,
                                              buffering_slack=0.3, theta_min=2))
    eng.policy.n_threshold = 2
    for i in range(3):
        eng.submit([1 + i, 2 + i, 3 + i], max_new=5)
    outs = eng.run()
    assert all(len(v) == 5 for v in outs.values())

    # 3) replay the engine's measured expert counts in the chiplet sim
    #    (expert dims scaled to the full granite sizes so the memory
    #    comparison is meaningful): FSE-DP must beat EP on memory
    import dataclasses
    spec = dataclasses.replace(spec_from_config(cfg), d_model=1024, d_expert=512)
    hw = PROTOTYPE_2X2
    counts_trace = [t["counts"] for t in eng.trace if t["counts"].sum() > 0][:4]
    assert counts_trace
    ratios = []
    for counts in counts_trace:
        per_chip = np.zeros((hw.num_chiplets, spec.num_experts), np.int64)
        for e, n in enumerate(counts):
            for j in range(int(n)):
                per_chip[j % hw.num_chiplets, e] += 1
        wl = LayerWorkload(counts=per_chip)
        r_fse = simulate_layer(hw, spec, wl, "fse_dp_paired")
        r_ep = simulate_layer(hw, spec, wl, "ep")
        # both fetch each active expert exactly once (work conservation)
        np.testing.assert_allclose(r_fse.ddr_bytes, r_ep.ddr_bytes)
        ratios.append(r_fse.peak_buffer_bytes / max(r_ep.peak_buffer_bytes, 1))
    # across the trace, FSE-DP's eager Rule-4 staging must not exceed EP's
    # whole-expert residency on average (tiny 6-activation layers are noisy,
    # hence the mean; large-workload dominance is asserted in test_sim)
    assert np.mean(ratios) <= 1.25, ratios
