"""Config registry: all assigned archs resolve, param counts match the
published sizes, reduced configs stay smoke-sized."""
import pytest

from repro.configs import (ASSIGNED_ARCHS, PAPER_MODELS, get_config,
                           list_configs, reduced_config)
from repro.configs.shapes import SHAPES, SHAPE_ORDER, applicable, cells

# published parameter counts (±12% tolerance: embeddings/norm conventions)
EXPECTED_B = {
    "nemotron-4-15b": 15.6, "yi-6b": 6.06, "stablelm-1.6b": 1.64,
    "nemotron-4-340b": 341.0, "jamba-v0.1-52b": 52.0, "whisper-base": 0.09,
    "granite-moe-1b-a400m": 1.33, "phi3.5-moe-42b-a6.6b": 41.9,
    "internvl2-2b": 1.9, "mamba2-370m": 0.37,
}


def test_all_archs_registered():
    for a in ASSIGNED_ARCHS:
        assert get_config(a).name == a
    assert len(ASSIGNED_ARCHS) == 10


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_param_counts(arch):
    got = get_config(arch).param_count() / 1e9
    want = EXPECTED_B[arch]
    assert abs(got - want) / want < 0.15, (arch, got, want)


def test_active_params():
    phi = get_config("phi3.5-moe-42b-a6.6b")
    assert 5.5e9 < phi.active_param_count() < 7.5e9      # 6.6B active
    dense = get_config("yi-6b")
    assert dense.active_param_count() == dense.param_count()


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_reduced_small(arch):
    r = reduced_config(arch)
    assert r.param_count() < 5e6


def test_shape_cells_total():
    """10 archs × 4 shapes = 40 cells; skips are annotated, never silent."""
    total = runnable = 0
    for a in ASSIGNED_ARCHS:
        for s, ok, why in cells(get_config(a)):
            total += 1
            runnable += ok
            if not ok:
                assert why
    assert total == 40
    # long_500k runs only for ssm+hybrid (2 of 10) => 40 - 8 skips
    assert runnable == 32


def test_long_context_applicability():
    assert applicable(get_config("mamba2-370m"), SHAPES["long_500k"])[0]
    assert applicable(get_config("jamba-v0.1-52b"), SHAPES["long_500k"])[0]
    assert not applicable(get_config("yi-6b"), SHAPES["long_500k"])[0]


def test_layer_plans():
    jamba = get_config("jamba-v0.1-52b")
    kinds = jamba.layer_kinds()
    assert kinds.count("attn") == 4 and kinds.count("ssm") == 28   # 1:7
    assert jamba.ffn_kinds().count("moe") == 16                    # every 2nd
    m2 = get_config("mamba2-370m")
    assert set(m2.layer_kinds()) == {"ssm"}


def test_paper_models_available():
    for m in PAPER_MODELS:
        assert get_config(m).moe is not None
