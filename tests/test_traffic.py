"""Poisson traffic harness + the closed-loop acceptance test:
continuous batching under load is bit-identical to sequential serving."""
import jax
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.models import api
from repro.serving import (Engine, Scheduler, SchedulerConfig, ServeConfig,
                           TrafficConfig, make_traffic, run_closed_loop,
                           to_sim_requests)

CHUNK = 4


@pytest.fixture(scope="module")
def setup():
    cfg = reduced_config("granite-moe-1b-a400m").replace(dtype="float32")
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _tcfg(cfg, n=32, seed=0):
    return TrafficConfig(num_requests=n, rate=0.8, avg_prompt=9,
                         max_prompt=20, min_new=2, max_new=4,
                         vocab=cfg.vocab_size, seed=seed)


# ---------------------------------------------------------------------------
# generator properties
# ---------------------------------------------------------------------------


def test_traffic_deterministic_and_poisson(setup):
    cfg, _ = setup
    t1 = make_traffic(_tcfg(cfg, n=64))
    t2 = make_traffic(_tcfg(cfg, n=64))
    assert [(t.arrival, t.prompt, t.max_new) for t in t1] \
        == [(t.arrival, t.prompt, t.max_new) for t in t2]
    arr = np.array([t.arrival for t in t1])
    gaps = np.diff(np.concatenate([[0.0], arr]))
    assert np.all(gaps > 0), "arrivals strictly ordered"
    # exponential gaps at rate 0.8: mean 1.25 time units (loose CI bound)
    assert 0.5 < gaps.mean() < 2.5
    assert make_traffic(_tcfg(cfg, seed=1))[0].prompt != t1[0].prompt


def test_traffic_mixed_lengths_and_skew(setup):
    cfg, _ = setup
    traffic = make_traffic(_tcfg(cfg))
    lens = [len(t.prompt) for t in traffic]
    assert len(traffic) == 32
    assert len(set(lens)) > 3, "mixed prompt lengths"
    assert any(l > 2 * CHUNK for l in lens), "some prompts > 2x chunk"
    assert all(t.max_new >= 2 for t in traffic)
    # Zipf affinity: prompt tokens are drawn from the request's private
    # Zipf slice of the vocab (the sim's sample_expert_probs with the
    # same affinity seed), so their mean probability beats uniform
    from repro.sim.workload import sample_expert_probs
    tc = _tcfg(cfg)
    for t in traffic[:6]:
        arng = np.random.default_rng(t.affinity_seed)
        probs = sample_expert_probs(tc.vocab, arng, zipf_s=tc.zipf_s)
        mean_p = float(np.mean(probs[t.prompt]))
        assert mean_p > 1.5 / tc.vocab, (mean_p, 1.0 / tc.vocab)
    # sim-side replay view mirrors the stream 1:1
    sim_reqs = to_sim_requests(traffic)
    assert [r.num_tokens for r in sim_reqs] == lens
    assert [r.affinity_seed for r in sim_reqs] \
        == [t.affinity_seed for t in traffic]


# ---------------------------------------------------------------------------
# acceptance: closed loop == sequential, token for token
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_closed_loop_matches_sequential(setup):
    """>= 32 Poisson-arrival requests with mixed prompt lengths (some
    > 2x the chunk size, some arriving while the batch is full) complete
    through the continuous-batching scheduler with per-request outputs
    bit-identical to serving the same requests sequentially
    one-at-a-time at the same seeds."""
    cfg, params = setup
    traffic = make_traffic(_tcfg(cfg, n=32))
    lens = [len(t.prompt) for t in traffic]
    assert sum(1 for l in lens if l > 2 * CHUNK) >= 4

    def scfg():
        return ServeConfig(max_batch=4, max_ctx=32, chunk_tokens=CHUNK)

    eng = Engine(params, cfg, scfg())
    sched = Scheduler(eng, SchedulerConfig(queue_capacity=64))
    queue_seen = []
    # sample queue depth each iteration to prove arrivals hit a full batch
    orig_step = sched.step

    def step_probe(dt=1.0):
        queue_seen.append((sched.queue_depth(), len(eng.free_slots)))
        return orig_step(dt)

    sched.step = step_probe
    res = run_closed_loop(sched, traffic)
    m = res["metrics"]
    assert m.completed == 32 and not res["dropped"] and m.rejected == 0
    assert any(q > 0 and free == 0 for q, free in queue_seen), \
        "some requests must arrive while the batch is full"
    assert m.queue_delay["p99"] > 0

    # sequential: the same requests one at a time, same seeds
    sequential = {}
    for t in traffic:
        e1 = Engine(params, cfg, scfg())
        r1 = e1.submit_chunked(t.prompt, t.max_new)
        sequential[t.rid] = e1.run()[r1]
    assert set(res["outputs"]) == set(sequential)
    for rid in sequential:
        assert res["outputs"][rid] == sequential[rid], \
            f"{rid} diverged under continuous batching"
        assert len(sequential[rid]) == \
            next(t.max_new for t in traffic if t.rid == rid)


def test_closed_loop_small_smoke(setup):
    """Fast-lane version: 6 requests end-to-end with metrics."""
    cfg, params = setup
    traffic = make_traffic(_tcfg(cfg, n=6))
    eng = Engine(params, cfg, ServeConfig(max_batch=2, max_ctx=32,
                                          chunk_tokens=CHUNK))
    sched = Scheduler(eng, SchedulerConfig(queue_capacity=8))
    res = run_closed_loop(sched, traffic)
    m = res["metrics"]
    assert m.completed == 6
    assert m.tokens_emitted == sum(t.max_new for t in traffic)
    assert m.ttft["p50"] > 0 and m.iterations > 0
    assert eng.stats["prefill_chunks"] > 0
    assert eng.stats["prefill_tokens"] == sum(len(t.prompt) for t in traffic)
