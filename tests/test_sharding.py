"""Sharding rules: divisibility guards, FSE-DP weight layout, cache specs."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, reduced_config
from repro.launch.specs import params_struct, decode_structs
from repro.configs.shapes import SHAPES
from repro.parallel import sharding as shd


class FakeMesh:
    """Shape-only stand-in (never touches jax devices)."""
    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)


MESH = FakeMesh({"data": 16, "model": 16})
MESH3 = FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_fit_divisibility():
    assert shd._fit(MESH, "model", 64) == "model"
    assert shd._fit(MESH, "model", 63) is None
    assert shd._fit(MESH, ("pod", "data"), 32) == "data"     # shrinks to data
    assert shd._fit(MESH3, ("pod", "data"), 32) == ("pod", "data")
    assert shd._fit(MESH3, ("pod", "data"), 16) == "data"    # shrinks


def test_moe_weight_layout_is_fse_dp():
    """d_expert must shard over model — one copy of every expert/group."""
    spec = shd.param_spec("periods/0/moe/w_up", (24, 32, 1024, 512), MESH, fsdp=False)
    assert spec == P(None, None, None, "model")
    spec = shd.param_spec("periods/0/moe/w_down", (24, 32, 512, 1024), MESH, fsdp=False)
    assert spec == P(None, None, "model", None)


def test_dense_ffn_tp():
    assert shd.param_spec("periods/0/ffn/w_up", (32, 2048, 8192), MESH, fsdp=False) \
        == P(None, None, "model")
    assert shd.param_spec("periods/0/ffn/w_down", (32, 8192, 2048), MESH, fsdp=True) \
        == P(None, "model", "data")


def test_attention_heads_tp():
    assert shd.param_spec("periods/0/attn/wq", (32, 4096, 4096), MESH, fsdp=False) \
        == P(None, None, "model")
    assert shd.param_spec("periods/0/attn/wo", (32, 4096, 4096), MESH, fsdp=False) \
        == P(None, "model", None)


def test_vocab_sharding():
    # embedding shards d_model (gather-friendly); lm_head shards vocab
    assert shd.param_spec("embed", (256000, 6144), MESH, fsdp=False) \
        == P(None, "model")
    assert shd.param_spec("lm_head", (6144, 256000), MESH, fsdp=False) \
        == P(None, "model")
    # d_model not divisible -> replicate that dim
    assert shd.param_spec("embed", (49155, 1023), MESH, fsdp=False) == P(None, None)


def test_norms_replicated():
    assert shd.param_spec("periods/0/norm1/scale", (32, 1024), MESH, fsdp=False) == P()


def test_cache_specs():
    # KV: (nper, B, S, kv, hd) — batch over dp, seq over model (SP decode)
    spec = shd.cache_spec("caches/0/kv/k", (32, 128, 32768, 8, 128), MESH,
                          batch_axes=("data",))
    assert spec == P(None, "data", "model", None, None)
    # batch=1 long-context: batch replicated, seq still sharded
    spec = shd.cache_spec("caches/0/kv/k", (4, 1, 524288, 8, 128), MESH,
                          batch_axes=("data",))
    assert spec == P(None, None, "model", None, None)
    spec = shd.cache_spec("caches/0/ssm/ssd", (48, 128, 32, 64, 128), MESH,
                          batch_axes=("data",))
    assert spec == P(None, "data", "model", None, None)


@pytest.mark.parametrize("arch", ["granite-moe-1b-a400m", "jamba-v0.1-52b",
                                  "mamba2-370m", "whisper-base"])
def test_param_specs_cover_all_leaves(arch):
    """Every parameter leaf of every family gets a valid spec whose axes
    divide the dims (the divisibility contract)."""
    cfg = get_config(arch)
    ps = params_struct(cfg)
    flat = jax.tree_util.tree_flatten_with_path(ps)[0]
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        spec = shd.param_spec(key, leaf.shape, MESH, fsdp=False)
        assert len(spec) <= len(leaf.shape), (key, spec, leaf.shape)
        for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * 10):
            if ax is not None:
                size = MESH.shape[ax] if isinstance(ax, str) else \
                    int(jnp.prod(jnp.asarray([MESH.shape[a] for a in ax])))
                assert dim % size == 0, (key, spec, leaf.shape)
