"""use_kernels(True) (Pallas, interpret) vs use_kernels(False) (jnp oracle)
parity for every FSE-DP shard_map mode on 8 fake devices — the acceptance
check that the ring step's expert GEMM really flows through the kernel
dispatch layer without changing results."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import MoEConfig
from repro.core import fse_dp, strategy
from repro.kernels import ops as kops
from repro.models import moe as moe_mod
from repro.parallel import meshctx

E, k, d, de = 8, 2, 32, 64
mesh = jax.make_mesh((2, 4), ("data", "model"))
x = jax.random.normal(jax.random.PRNGKey(2), (4, 16, d), jnp.float32)


def run(activation, enabled):
    moe = MoEConfig(num_experts=E, top_k=k, d_expert=de,
                    capacity_factor=E / k, micro_slices=2)
    params = moe_mod.moe_init(jax.random.PRNGKey(1), d, moe, activation,
                              jnp.float32)
    outs = {}
    with meshctx.with_mesh(mesh), kops.use_kernels(enabled):
        y, _ = jax.jit(lambda p, x: strategy.execute("fse_dp", p, x, moe, activation))(params, x)
        outs["stream"] = np.asarray(y)
        for body, nm in [(fse_dp._local_moe_index, "index"),
                         (fse_dp._local_moe_slice, "slice")]:
            fn = functools.partial(body, moe=moe, activation=activation,
                                   axis="model", P_=4,
                                   pm_axes=("data", "model"))
            xs = P(("data",), None, None)
            wspecs = (P(None, None), P(None, None, "model"),
                      P(None, None, "model"), P(None, "model", None))
            if activation == "swiglu":
                sm = fse_dp.shard_map(
                    lambda x, wr, wg, wu, wd: fn(x, wr, wg, wu, wd),
                    mesh=mesh, in_specs=(xs,) + wspecs, out_specs=(xs, P()))
                y, _ = jax.jit(sm)(x, params["router"]["w_router"],
                                   params["w_gate"], params["w_up"],
                                   params["w_down"])
            else:  # gateless: no w_gate operand anywhere
                sm = fse_dp.shard_map(
                    lambda x, wr, wu, wd: fn(x, wr, None, wu, wd),
                    mesh=mesh, in_specs=(xs, wspecs[0], wspecs[2], wspecs[3]),
                    out_specs=(xs, P()))
                y, _ = jax.jit(sm)(x, params["router"]["w_router"],
                                   params["w_up"], params["w_down"])
            outs[nm] = np.asarray(y)
    return outs


for activation in ("swiglu", "gelu"):
    with_kernel = run(activation, True)
    with_ref = run(activation, False)
    for mode in ("stream", "index", "slice"):
        err = float(np.max(np.abs(with_kernel[mode] - with_ref[mode])))
        print(f"{activation:8s} {mode:8s} maxerr={err:.2e}")
        assert err < 2e-5, (activation, mode, err)
print("KERNEL PARITY OK")
