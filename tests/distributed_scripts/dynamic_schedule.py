"""Dynamic trajectory scheduling on 8 fake devices: for every
distributed family (fse_dp stream/index/slice forced + planned, ep, tp)
and for a host-built EMA schedule, ``schedule=dynamic`` must produce
exactly the arrays of the static run — the paper's virtualization
argument (scheduling changes expert execution order/timing only, never
values), checked bit for bit through the real shard_map lowerings."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import MoEConfig
from repro.core import autotune, strategy as strat, trajectory
from repro.core.strategy import ExecutionSpec
from repro.models import moe as moe_mod
from repro.parallel import meshctx

moe = MoEConfig(num_experts=8, top_k=2, d_expert=64, capacity_factor=4.0,
                micro_slices=2)
D = 32
params = moe_mod.moe_init(jax.random.PRNGKey(0), D, moe, "swiglu",
                          jnp.float32)
mesh = jax.make_mesh((2, 4), ("data", "model"))
x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, D), jnp.float32)

with meshctx.with_mesh(mesh):
    # families via the registry spec knob (in-graph traced trajectory)
    for fam in ("fse_dp", "ep", "tp"):
        ys, auxs = jax.jit(lambda p, xx, f=fam: strat.execute(
            f, p, xx, moe, "swiglu"))(params, x)
        yd, auxd = jax.jit(lambda p, xx, f=fam: strat.execute(
            ExecutionSpec(strategy=f, schedule="dynamic"),
            p, xx, moe, "swiglu"))(params, x)
        assert np.array_equal(np.asarray(ys), np.asarray(yd)), \
            f"{fam}: dynamic != static (max diff " \
            f"{np.abs(np.asarray(ys) - np.asarray(yd)).max():.2e})"
        assert np.array_equal(np.asarray(auxs), np.asarray(auxd)), fam
        print(f"{fam}: dynamic == static bit-identical")

    # every forced FSE-DP mode (B_grp=2 per model group, S=16, P=4)
    for mode in ("stream", "index", "slice"):
        plan = autotune.plan_moe(2, 16, D, moe, "swiglu", 4, mode=mode)
        ys, _ = strat.execute("fse_dp", params, x, moe, "swiglu", plan=plan)
        yd, _ = strat.execute(ExecutionSpec(strategy="fse_dp",
                                            schedule="dynamic"),
                              params, x, moe, "swiglu", plan=plan)
        assert np.array_equal(np.asarray(ys), np.asarray(yd)), mode
        print(f"fse_dp[{mode}]: dynamic == static bit-identical")

    # host-built EMA schedule (the serving engine's feedback path),
    # including a load-aware re-plan from the same EMA vector
    tracker = trajectory.LoadTracker(moe.num_experts, decay=0.8)
    rng = np.random.default_rng(0)
    for _ in range(4):
        tracker.update(rng.integers(0, 12, size=moe.num_experts))
    plan = autotune.plan_moe(2, 16, D, moe, "swiglu", 4,
                             load=tracker.load_vector())
    sched = tracker.schedule(plan=plan)
    assert sched.order is not None and sched.plan is not None
    ys, _ = strat.execute("fse_dp", params, x, moe, "swiglu", plan=plan)
    yd, _ = strat.execute("fse_dp", params, x, moe, "swiglu", schedule=sched)
    assert np.array_equal(np.asarray(ys), np.asarray(yd)), "EMA schedule"
    print("fse_dp[EMA host schedule + load-aware plan]: bit-identical")

    # a host-built (global-order) schedule on the expert-sharded EP body:
    # the body must re-derive its owned-expert trajectory locally, not
    # apply the global E-length order to its E_loc shard
    ys, _ = strat.execute("ep", params, x, moe, "swiglu")
    yd, _ = strat.execute("ep", params, x, moe, "swiglu",
                          schedule=tracker.schedule())
    assert np.array_equal(np.asarray(ys), np.asarray(yd)), "EP host schedule"
    print("ep[EMA host schedule]: bit-identical")

print("DYNAMIC SCHEDULE PARITY OK")
