import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import functools
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.configs.base import MoEConfig
from repro.models import moe as moe_mod
from repro.core import gating, fse_dp, strategy
from repro.parallel import meshctx

E, k, d, de = 8, 2, 32, 64
moe = MoEConfig(num_experts=E, top_k=k, d_expert=de, capacity_factor=E/k, micro_slices=2)
key = jax.random.PRNGKey(1)
params = moe_mod.moe_init(key, d, moe, "swiglu", jnp.float32)

mesh = jax.make_mesh((2, 4), ("data", "model"))
B, S = 4, 16
x = jax.random.normal(jax.random.PRNGKey(2), (B, S, d), jnp.float32)

# oracle (dense)
x2d = x.reshape(-1, d)
routing = gating.route(params["router"], x2d, top_k=moe.top_k)
y_ref = moe_mod.moe_dense(params, x2d, routing, "swiglu").reshape(B, S, d)

with meshctx.with_mesh(mesh):
    for name in ("fse_dp", "ep", "tp"):
        y, aux = jax.jit(lambda p, x, n=name: strategy.execute(n, p, x, moe, "swiglu"))(params, x)
        err = float(jnp.max(jnp.abs(y - y_ref)))
        print(f"{name:8s} maxerr={err:.2e} aux={float(aux):.4f}")
        assert err < 2e-4, (name, err)
    # index + slice modes directly
    for mode_body, nm in [(fse_dp._local_moe_index, "index"), (fse_dp._local_moe_slice, "slice")]:
        
        body = functools.partial(mode_body, moe=moe, activation="swiglu", axis="model", P_=4, pm_axes=("data","model"))
        xs = P(("data",), None, None)
        y, aux = jax.jit(fse_dp.shard_map(
            lambda x, wr, wg, wu, wd: body(x, wr, wg, wu, wd), mesh=mesh,
            in_specs=(xs, P(None,None), P(None,None,"model"), P(None,None,"model"), P(None,"model",None)),
            out_specs=(xs, P())))(x, params["router"]["w_router"], params["w_gate"], params["w_up"], params["w_down"])
        err = float(jnp.max(jnp.abs(y - y_ref)))
        print(f"{nm:8s} maxerr={err:.2e}")
        assert err < 2e-4, (nm, err)
print("ALL MODES MATCH ORACLE")
