"""Gradients flow through the FSE-DP ring (ppermute transpose) and match
the single-device capacity implementation."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import MoEConfig
from repro.core import strategy
from repro.models import moe as moe_mod
from repro.parallel import meshctx

E, k, d, de = 8, 2, 32, 64
moe = MoEConfig(num_experts=E, top_k=k, d_expert=de, capacity_factor=E / k,
                micro_slices=2)
params = moe_mod.moe_init(jax.random.PRNGKey(1), d, moe, "swiglu", jnp.float32)
mesh = jax.make_mesh((2, 4), ("data", "model"))
x = jax.random.normal(jax.random.PRNGKey(2), (4, 16, d), jnp.float32)


def loss_dist(p, x):
    with meshctx.with_mesh(mesh):
        y, aux = strategy.execute("fse_dp", p, x, moe, "swiglu")
    return jnp.sum(y ** 2) + 0.0 * aux


def loss_ref(p, x):
    from repro.core import gating
    x2d = x.reshape(-1, d)
    r = gating.route(p["router"], x2d, top_k=k)
    y = moe_mod.moe_capacity(p, x2d, r, moe, "swiglu")
    return jnp.sum(y ** 2)


g1 = jax.jit(jax.grad(loss_dist))(params, x)
g2 = jax.grad(loss_ref)(params, x)
for key in ("w_gate", "w_up", "w_down"):
    np.testing.assert_allclose(np.asarray(g1[key]), np.asarray(g2[key]),
                               rtol=5e-3, atol=5e-4)
print("FSE-DP gradients match reference")
