"""Plan-driven dispatch == forced-mode execution, bit for bit, on 8 fake
devices: for each of stream/index/slice, ``strategy.execute("fse_dp", ..., plan=...)`` must
produce exactly the arrays of a hand-built shard_map over the same body
with the same micro-slice count and kernel tile opts.  Also checks the
default (auto) plan equals its own forced re-execution, and that the
level='off' fallback reproduces the legacy static dispatch."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import functools
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.configs.base import MoEConfig
from repro.core import autotune, fse_dp, strategy
from repro.models import moe as moe_mod
from repro.parallel import meshctx

E, k, d, de = 8, 2, 32, 64
moe = MoEConfig(num_experts=E, top_k=k, d_expert=de, capacity_factor=E / k,
                micro_slices=2)
params = moe_mod.moe_init(jax.random.PRNGKey(1), d, moe, "swiglu", jnp.float32)
mesh = jax.make_mesh((2, 4), ("data", "model"))
B, S = 4, 16
x = jax.random.normal(jax.random.PRNGKey(2), (B, S, d), jnp.float32)
P_ = 4
B_grp = B // 2                                   # data axis is 2-way

BODIES = {"stream": fse_dp._local_moe_stream,
          "index": fse_dp._local_moe_index,
          "slice": fse_dp._local_moe_slice}


def forced_reference(plan):
    """Hand-built shard_map mirroring the fse_dp strategy for this plan."""
    body = BODIES[plan.mode]
    kopts = tuple(sorted(plan.kernel_opts().items()))
    fn = functools.partial(body, moe=moe, activation="swiglu", axis="model",
                           P_=P_, pm_axes=("data", "model"),
                           micro_slices=plan.micro_slices, kopts=kopts)
    xs = P(("data",), "model" if plan.mode == "stream" else None, None)
    return jax.jit(fse_dp.shard_map(
        lambda x, wr, wg, wu, wd: fn(x, wr, wg, wu, wd), mesh=mesh,
        in_specs=(xs, P(None, None), P(None, None, "model"),
                  P(None, None, "model"), P(None, "model", None)),
        out_specs=(xs, P())))(
        x, params["router"]["w_router"], params["w_gate"],
        params["w_up"], params["w_down"])


with meshctx.with_mesh(mesh):
    for mode in ("stream", "index", "slice"):
        plan = autotune.plan_moe(B_grp, S, d, moe, "swiglu", P_,
                                 dtype_bytes=4, mode=mode)
        y_plan, aux_plan = jax.jit(
            lambda p, x: strategy.execute("fse_dp", p, x, moe, "swiglu", plan=plan)
        )(params, x)
        y_ref, aux_ref = forced_reference(plan)
        assert np.array_equal(np.asarray(y_plan), np.asarray(y_ref)), \
            f"{mode}: plan-driven != forced (max diff " \
            f"{np.abs(np.asarray(y_plan) - np.asarray(y_ref)).max():.2e})"
        assert np.array_equal(np.asarray(aux_plan), np.asarray(aux_ref)), mode
        print(f"{mode:8s} plan-driven == forced  M={plan.micro_slices} "
              f"kopts={plan.kernel_opts()}")

    # default (auto) plan == its own forced re-execution
    auto = autotune.plan_moe(B_grp, S, d, moe, "swiglu", P_, dtype_bytes=4)
    y_auto, _ = jax.jit(
        lambda p, x: strategy.execute("fse_dp", p, x, moe, "swiglu"))(params, x)
    y_ref, _ = forced_reference(auto)
    assert np.array_equal(np.asarray(y_auto), np.asarray(y_ref))
    print(f"auto plan ({auto.mode}, source={auto.source}) == forced")

    # level='off' reproduces the legacy static heuristic dispatch
    with autotune.use_autotune("off"):
        off = autotune.plan_moe(B_grp, S, d, moe, "swiglu", P_, dtype_bytes=4)
        assert off.source == "fallback" and off.mode == "stream" \
            and off.micro_slices == moe.micro_slices
        y_off, _ = jax.jit(
            lambda p, x: strategy.execute("fse_dp", p, x, moe, "swiglu"))(params, x)
    y_ref_off, _ = forced_reference(off)
    assert np.array_equal(np.asarray(y_off), np.asarray(y_ref_off))
    print("off-level fallback == legacy static dispatch")

print("AUTOTUNE PLAN PARITY OK")
