"""ExecutionSpec per-layer overrides on 8 fake devices: a 4-layer MoE
stack with ``layer_overrides`` = {fse_dp on even layers, ep on odd}
must produce exactly the arrays of (a) a hand-built per-layer loop that
forces each layer's strategy directly and (b) per-layer forced
``moe_block`` calls — proving spec resolution + the unrolled period
loop change nothing but the dataflow."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, MoEConfig
from repro.core.strategy import ExecutionSpec
from repro.models import moe as moe_mod, transformer
from repro.models.layers import apply_norm
from repro.parallel import meshctx
from repro.parallel.sharding import constrain_seq_sharded

cfg = ModelConfig(
    name="toy-moe-4l", family="moe", num_layers=4, d_model=32,
    num_heads=4, num_kv_heads=4, d_ff=0, vocab_size=64,
    moe=MoEConfig(num_experts=8, top_k=2, d_expert=64,
                  capacity_factor=4.0, micro_slices=2, impl="fse_dp"),
    dtype="float32")

mesh = jax.make_mesh((2, 4), ("data", "model"))
params = transformer.init_lm(jax.random.PRNGKey(0), cfg)
B, S = 4, 16
tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)

FORCED = ["fse_dp", "ep", "fse_dp", "ep"]
spec = ExecutionSpec(strategy="fse_dp",
                     layer_overrides={i: n for i, n in enumerate(FORCED)})

p, plan = transformer.period_plan(cfg)
assert p == 1 and cfg.num_layers // p == 4
positions = jnp.arange(S)[None, :]


def fwd_spec(params, tokens):
    return transformer.forward(params, tokens, cfg, spec=spec)


def fwd_forced(params, tokens):
    """Independent per-layer loop forcing each layer's strategy name,
    mirroring forward's SP constraints around each period."""
    x = params["embed"][tokens]
    aux = jnp.zeros((), jnp.float32)
    for c in range(cfg.num_layers):
        x = constrain_seq_sharded(x)
        slot = jax.tree.map(lambda a: a[c], params["periods"][0])
        x, a = transformer._apply_slot_full(
            slot, x, cfg, "attn", "moe", positions=positions,
            spec=ExecutionSpec(strategy=FORCED[c]), phase="train")
        aux = aux + a
        x = constrain_seq_sharded(x)
    x = apply_norm(cfg.norm, params["final_norm"], x)
    return x @ params["lm_head"], aux


with meshctx.with_mesh(mesh):
    y1, aux1 = jax.jit(fwd_spec)(params, tokens)
    y2, aux2 = jax.jit(fwd_forced)(params, tokens)
    assert np.array_equal(np.asarray(y1), np.asarray(y2)), \
        f"spec-override forward != per-layer forced (max diff " \
        f"{np.abs(np.asarray(y1) - np.asarray(y2)).max():.2e})"
    assert np.array_equal(np.asarray(aux1), np.asarray(aux2))
    print(f"forward with layer_overrides == per-layer forced "
          f"(logits {tuple(y1.shape)})")

    # block-level: spec resolution picks the forced strategy per layer
    h = jax.random.normal(jax.random.PRNGKey(2), (B, S, cfg.d_model),
                          jnp.float32)
    moe_params = jax.tree.map(lambda a: a[0], params["periods"][0])["moe"]
    for i, forced in enumerate(FORCED):
        ya = jax.jit(lambda pp, hh, i=i: moe_mod.moe_block(
            pp, hh, cfg.moe, cfg.activation, spec=spec, layer=i))(moe_params, h)
        yb = jax.jit(lambda pp, hh, n=forced: moe_mod.moe_block(
            pp, hh, cfg.moe, cfg.activation, impl=n))(moe_params, h)
        assert np.array_equal(np.asarray(ya), np.asarray(yb)), (i, forced)
    print("moe_block(spec, layer=i) == moe_block(impl=forced[i]) for all i")

print("LAYER OVERRIDES OK")
