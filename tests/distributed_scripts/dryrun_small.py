import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, time
from repro.configs import reduced_config
from repro.configs.shapes import ShapeSpec
from repro.launch.steps import build_step
from repro.launch import analysis
from repro.parallel import meshctx
from repro.kernels import ops as kops

mesh = jax.make_mesh((2, 4), ("data", "model"))
for arch in ["granite-moe-1b-a400m", "jamba-v0.1-52b", "mamba2-370m", "whisper-base", "internvl2-2b"]:
    cfg = reduced_config(arch)
    if cfg.moe is not None:
        import dataclasses
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, impl="fse_dp"))
    for kind, shape in [("train", ShapeSpec("t", 64, 8, "train")),
                        ("prefill", ShapeSpec("p", 64, 8, "prefill")),
                        ("decode", ShapeSpec("d", 64, 8, "decode"))]:
        t0 = time.time()
        with meshctx.with_mesh(mesh), kops.use_kernels(False):
            fn, in_sh, out_sh, structs = build_step(cfg, shape, mesh)
            lowered = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh).lower(*structs)
            compiled = lowered.compile()
        cost = analysis.cost_dict(compiled)
        coll = analysis.collective_bytes(compiled.as_text())
        mem = compiled.memory_analysis()
        print(f"{arch:24s} {kind:8s} ok {time.time()-t0:5.1f}s flops={cost.get('flops',0):.2e} "
              f"bytes={cost.get('bytes accessed',0):.2e} coll={coll['total']:.2e} "
              f"arg={getattr(mem,'argument_size_in_bytes',None)} temp={getattr(mem,'temp_size_in_bytes',None)}")
