"""Quantized expert streaming (kernels.quant) + EMA-hot weight tiering.

Tolerance contract (docs/quantization.md): the Pallas kernel must match
the *quantized* jnp oracle (`ref.streamed_moe_quant_ref` — the identical
quantize→dequantize round-trip) tightly for int8/fp8/fp32 and within a
looser bf16 bound (the kernel's h-cast before the down GEMM); the
quantized oracle itself sits within a documented relative-Frobenius
error of the fp32 reference.  Tiering is accounting-only: tokens and
trace counts are bit-identical with the tier on or off.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.strategy import ExecutionSpec
from repro.kernels import ops, quant, ref
from repro.kernels.streamed_moe import streamed_moe_kernel

# kernel vs quantized-oracle tolerance per streamed format
KERNEL_TOL = {"fp32": 2e-5, "int8": 2e-5, "fp8": 2e-5, "bf16": 2e-2}
# quantized-oracle vs fp32-oracle relative Frobenius error ceiling
ORACLE_REL = {"fp32": 0.0, "bf16": 0.01, "int8": 0.02, "fp8": 0.06}


def _shapes(E=3, C=37, d=32, m=24, key=0):
    ks = jax.random.split(jax.random.PRNGKey(key), 4)
    xe = jax.random.normal(ks[0], (E, C, d), jnp.float32)
    wg = jax.random.normal(ks[1], (E, d, m), jnp.float32) * 0.1
    wu = jax.random.normal(ks[2], (E, d, m), jnp.float32) * 0.1
    wd = jax.random.normal(ks[3], (E, m, d), jnp.float32) * 0.1
    return xe, wg, wu, wd


# ---------------------------------------------------------------------------
# the quant module itself
# ---------------------------------------------------------------------------


def test_weight_bytes_table():
    assert quant.weight_bytes("fp32") == 4
    assert quant.weight_bytes("bf16") == 2
    assert quant.weight_bytes("int8") == 1
    assert quant.weight_bytes("fp8") == 1
    assert quant.weight_bytes() is None
    assert quant.weight_bytes(default=2) == 2
    with quant.use_weight_dtype("int8"):
        assert quant.weight_dtype() == "int8"
        assert quant.weight_bytes(default=2) == 1
    assert quant.weight_dtype() is None


def test_unknown_weight_dtype_rejected():
    with pytest.raises(ValueError):
        quant.check_weight_dtype("int4")
    with pytest.raises(ValueError):
        ExecutionSpec(strategy="capacity", weight_dtype="e5m2")


@pytest.mark.parametrize("wd", ["int8", "fp8"])
def test_quantize_shapes_and_roundtrip(wd):
    _, wg, _, wdn = _shapes()
    q, s = quant.quantize(wg, wd)                 # (E,d,m) -> scales (E,1,m)
    assert q.shape == wg.shape and s.shape == (wg.shape[0], 1, wg.shape[2])
    assert jnp.dtype(q.dtype).itemsize == 1
    back = quant.dequantize(q, s)
    # int8 rounds to the nearest scale step (error <= scale/2); fp8 e4m3
    # carries 3 mantissa bits, so error is *relative*: <= 2^-4 of the value
    err = np.abs(np.asarray(back - wg))
    if wd == "int8":
        bound = np.asarray(s) * 0.51
    else:
        bound = np.abs(np.asarray(wg)) * 2.0 ** -4 + np.asarray(s) * 0.01
    assert (err <= bound + 1e-7).all()
    q2, s2 = quant.quantize(wdn, wd)              # (E,m,d) -> scales (E,1,d)
    assert s2.shape == (wdn.shape[0], 1, wdn.shape[2])


# ---------------------------------------------------------------------------
# kernel vs quantized oracle: activations x tilings x formats
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("wd", ["fp32", "bf16", "int8", "fp8"])
@pytest.mark.parametrize("act", ["swiglu", "relu2", "gelu"])
def test_kernel_matches_quant_oracle(act, wd):
    xe, wg, wu, wd_ = _shapes()
    wg = wg if act == "swiglu" else None
    with ops.use_kernels(True):
        got = ops.streamed_moe(xe, wg, wu, wd_, act, weight_dtype=wd,
                               token_tile=16, interpret=True)
    want = ref.streamed_moe_quant_ref(xe, wg, wu, wd_, act, wd)
    tol = KERNEL_TOL[wd]
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)


@pytest.mark.parametrize("wd", ["int8", "fp8"])
@pytest.mark.parametrize("dm_tile,de_tile", [(8, 8), (16, 12), (32, 24)])
def test_kernel_quant_tiled_matches_oracle(wd, dm_tile, de_tile):
    """Scale side-operands must block-index correctly under d_model and
    d_expert tiling (C=37 with token_tile=16 also covers row masking)."""
    xe, wg, wu, wd_ = _shapes()
    with ops.use_kernels(True):
        got = ops.streamed_moe(xe, wg, wu, wd_, "swiglu", weight_dtype=wd,
                               token_tile=16, dmodel_tile=dm_tile,
                               dexpert_tile=de_tile, interpret=True)
    want = ref.streamed_moe_quant_ref(xe, wg, wu, wd_, "swiglu", wd)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("wd", ["bf16", "int8", "fp8"])
def test_quant_oracle_near_fp32_oracle(wd):
    """The streamed format's information loss stays within the documented
    relative-Frobenius ceiling of the exact fp32 reference."""
    xe, wg, wu, wd_ = _shapes()
    exact = np.asarray(ref.streamed_moe_ref(xe, wg, wu, wd_, "swiglu"))
    qq = np.asarray(ref.streamed_moe_quant_ref(xe, wg, wu, wd_, "swiglu", wd))
    rel = np.linalg.norm(qq - exact) / np.linalg.norm(exact)
    assert rel <= ORACLE_REL[wd], f"{wd}: rel error {rel:.4f}"


@pytest.mark.parametrize("wd", ["int8", "fp8"])
def test_ambient_dispatch_and_oracle_parity(wd):
    """ExecutionSpec.scope() threads the format ambiently: the kernel
    branch and the use_kernels(False) oracle branch agree at the kernel
    tolerance, with no explicit weight_dtype kwarg anywhere."""
    xe, wg, wu, wd_ = _shapes()
    sp = ExecutionSpec(strategy="capacity", weight_dtype=wd)
    with sp.scope(), ops.use_kernels(True):
        y_k = ops.streamed_moe(xe, wg, wu, wd_, "swiglu", interpret=True)
    with sp.scope(), ops.use_kernels(False):
        y_r = ops.streamed_moe(xe, wg, wu, wd_, "swiglu")
    np.testing.assert_allclose(y_k, y_r, rtol=2e-5, atol=2e-5)


def test_quantized_kernel_ships_scale_operands():
    """Gateless quantized lowering carries exactly x, w_u, w_d + 2 scale
    rows — and the weight operands enter the pallas_call at 1 byte."""
    xe, _, wu, wd_ = _shapes()

    def f(xe, wu, wd_):
        with ops.use_kernels(True):
            return ops.streamed_moe(xe, None, wu, wd_, "gelu",
                                    weight_dtype="int8", interpret=True)

    jaxpr = jax.make_jaxpr(f)(xe, wu, wd_)
    calls = [e for e in jaxpr.jaxpr.eqns if e.primitive.name == "pallas_call"]
    if not calls:  # custom_vjp wraps the call one level down
        for e in jaxpr.jaxpr.eqns:
            for sub in (e.params.get("call_jaxpr"), e.params.get("fun_jaxpr")):
                if sub is None:
                    continue
                sub = getattr(sub, "jaxpr", sub)
                calls += [q for q in sub.eqns
                          if q.primitive.name == "pallas_call"]
    assert calls, "expected a pallas_call in the jaxpr"
    avals = [v.aval for v in calls[0].invars]
    assert len(avals) == 5                       # xe, w_u, w_d, s_u, s_d
    assert sum(jnp.dtype(a.dtype).itemsize == 1 for a in avals) == 2


def test_quantized_gradients_are_straight_through():
    """The custom VJP differentiates the fp32 oracle of the *original*
    weights (STE), so grads are finite and match the unquantized ones."""
    xe, wg, wu, wd_ = _shapes()

    def loss(wu, wg, wdt):
        return jnp.sum(ops.streamed_moe(xe, wg, wu, wd_, "swiglu",
                                        weight_dtype=wdt,
                                        interpret=True) ** 2)

    with ops.use_kernels(True):
        g_q = jax.grad(loss)(wu, wg, "int8")
        g_f = jax.grad(loss)(wu, wg, None)
    assert np.isfinite(np.asarray(g_q)).all()
    # STE: same backward function, different forward residual — the only
    # difference is the cotangent from the (slightly different) output,
    # so grads track the unquantized ones loosely but globally
    g_q, g_f = np.asarray(g_q), np.asarray(g_f)
    rel = np.linalg.norm(g_q - g_f) / np.linalg.norm(g_f)
    assert rel <= 0.05, f"STE grad drifted {rel:.3f} from fp32 grad"


# ---------------------------------------------------------------------------
# planner: quantized weight bytes re-validate rank agreement
# ---------------------------------------------------------------------------


def test_mode_ranking_agrees_with_simulator_quantized():
    """Acceptance: >=80% top-choice agreement with the discrete referee
    when the streamed expert weights are 1 byte/param (int8/fp8)."""
    from repro.core.autotune import (HardwareProfile, VALIDATION_SWEEP,
                                     plan_moe)
    from repro.configs.base import MoEConfig
    from repro.sim import modes as sim_modes
    from repro.sim.hardware import ModelSpec, scaled
    hw_of = {2: scaled(1, 2), 4: scaled(2, 2), 8: scaled(2, 4)}
    agree, rows = 0, []
    for (B, S, E, de, P) in VALIDATION_SWEEP:
        hw = hw_of[P]
        profile = HardwareProfile.from_chiplet(hw)
        spec = ModelSpec("sweep", 512, de, E, 2, bytes_per_param=1)
        plan = plan_moe(B, S, 512, MoEConfig(num_experts=E, top_k=2,
                                             d_expert=de, micro_slices=4),
                        "swiglu", P, profile=profile, level="analytic",
                        weight_bytes=1)
        sim = sim_modes.rank_modes(hw, spec, B * S, B=B, S=S)
        best = min(sim, key=sim.get)
        agree += plan.mode == best
        rows.append((B, S, E, de, P, plan.mode, best))
    frac = agree / len(VALIDATION_SWEEP)
    assert frac >= 0.8, f"quantized rank agreement {frac:.2f} < 0.8: {rows}"


def test_plan_cost_drops_with_weight_bytes():
    """Halving streamed bytes must never raise the planned layer cost."""
    from repro.core.autotune import plan_moe
    from repro.configs.base import MoEConfig
    moe = MoEConfig(num_experts=16, top_k=2, d_expert=512, micro_slices=4)
    c2 = plan_moe(4, 64, 512, moe, "swiglu", 4, level="analytic",
                  weight_bytes=2).predicted_s
    c1 = plan_moe(4, 64, 512, moe, "swiglu", 4, level="analytic",
                  weight_bytes=1).predicted_s
    assert 0 < c1 <= c2


# ---------------------------------------------------------------------------
# EMA-hot expert weight tiering (serving engine accounting)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def served():
    from repro.configs import reduced_config
    from repro.models import api
    cfg = reduced_config("granite-moe-1b-a400m").replace(dtype="float32")
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _serve(cfg, params, budget_mb, wd=None, schedule="dynamic"):
    from repro.serving import Engine, ServeConfig
    sp = ExecutionSpec(strategy="capacity", schedule=schedule,
                       weight_dtype=wd)
    eng = Engine(params, cfg, ServeConfig(max_batch=4, max_ctx=48, spec=sp,
                                          resident_budget_mb=budget_mb))
    rids = [eng.submit(list(p), max_new=5) for p in ((1, 2, 3, 4), (9, 8, 7))]
    outs = eng.run()
    return eng, [outs[r] for r in rids]


def test_tiering_is_bit_identical(served):
    """The tier is pure accounting: tokens, trace counts and trajectories
    are unchanged; only residency/DDR bookkeeping differs."""
    cfg, params = served
    e0, o0 = _serve(cfg, params, 0.0, wd="int8")
    e1, o1 = _serve(cfg, params, 0.05, wd="int8")
    assert o0 == o1
    r0 = [r for r in e0.trace if "counts" in r]
    r1 = [r for r in e1.trace if "counts" in r]
    assert len(r0) == len(r1)
    for a, b in zip(r0, r1):
        np.testing.assert_array_equal(a["counts"], b["counts"])
        assert a.get("trajectory") == b.get("trajectory")
        assert "resident" not in a and "resident" in b
    assert e0.stats["ddr_bytes_saved"] == 0
    assert e1.stats["ddr_bytes_saved"] > 0
    assert e1.stats["resident_weight_bytes"] > 0
    m0 = sum(r["modeled_s"] for r in r0)
    m1 = sum(r["modeled_s"] for r in r1)
    assert m1 < m0                    # resident experts skip DDR terms


def test_quantized_clock_halves_ddr(served):
    """int8 weights halve the modeled expert-weight stream vs the bf16
    default clock (DDR-bound regime, so modeled seconds drop)."""
    cfg, params = served
    e_bf, _ = _serve(cfg, params, 0.0, wd=None)
    e_q, _ = _serve(cfg, params, 0.0, wd="int8")
    assert e_q.cost_model.expert_bytes * 2 == e_bf.cost_model.expert_bytes
    m_bf = sum(r["modeled_s"] for r in e_bf.trace if "modeled_s" in r)
    m_q = sum(r["modeled_s"] for r in e_q.trace if "modeled_s" in r)
    assert m_q < m_bf


@pytest.mark.parametrize("schedule", ["dynamic", "static"])
def test_modeled_clock_agrees_with_referee_under_tiering(served, schedule):
    """Closed-form residency accounting vs the discrete replay referee at
    *partial* residency (resident < active — the regime the tier is
    for): aggregate agreement within 5%, and both sides agree the tier
    saves time."""
    from repro.sim import hardware, modes
    cfg, params = served
    e0, _ = _serve(cfg, params, 0.0, wd="int8", schedule=schedule)
    e1, _ = _serve(cfg, params, 0.05, wd="int8", schedule=schedule)
    assert 0 < e1._n_resident < cfg.moe.num_experts
    spec = hardware.spec_from_config(cfg, weight_bytes=1)
    for eng in (e0, e1):
        modeled = sum(r["modeled_s"] for r in eng.trace if "modeled_s" in r)
        referee = modes.replay_trace(hardware.PROTOTYPE_2X2, spec, eng.trace)
        assert abs(modeled - referee) / referee <= 0.05, \
            f"{schedule}: modeled {modeled:.3e} vs referee {referee:.3e}"
    ref0 = modes.replay_trace(hardware.PROTOTYPE_2X2, spec, e0.trace)
    ref1 = modes.replay_trace(hardware.PROTOTYPE_2X2, spec, e1.trace)
    assert ref1 < ref0


def test_negative_resident_budget_rejected(served):
    from repro.serving import ServeConfig
    with pytest.raises(ValueError):
        ServeConfig(resident_budget_mb=-1.0)
