"""Data pipeline: determinism (the restart contract) and learnability."""
import numpy as np
from _hyp import given, settings, st

from repro.data import DataConfig, SyntheticLM, batch_for

CFG = DataConfig(vocab_size=64, seq_len=16, global_batch=4, seed=7)


def test_deterministic_per_step():
    a = SyntheticLM(CFG).batch(5)
    b = SyntheticLM(CFG).batch(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    np.testing.assert_array_equal(a["labels"], b["labels"])


def test_steps_differ():
    a = SyntheticLM(CFG).batch(1)
    b = SyntheticLM(CFG).batch(2)
    assert not np.array_equal(a["tokens"], b["tokens"])


def test_labels_are_shifted_tokens():
    ds = SyntheticLM(CFG)
    b = ds.batch(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000))
def test_pure_function_of_step(step):
    np.testing.assert_array_equal(batch_for(CFG, step)["tokens"],
                                  batch_for(CFG, step)["tokens"])


def test_markov_structure_learnable():
    """Successors come from a small per-token set (bigram learnability)."""
    ds = SyntheticLM(CFG)
    succ = {}
    for s in range(20):
        b = ds.batch(s)
        for row_t, row_l in zip(b["tokens"], b["labels"]):
            for t, l in zip(row_t, row_l):
                succ.setdefault(int(t), set()).add(int(l))
    avg = np.mean([len(v) for v in succ.values()])
    assert avg <= CFG.branching + 0.01
