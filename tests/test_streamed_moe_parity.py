"""CPU-interpret parity for the streamed-MoE Pallas kernel and its
``kernels.ops`` dispatch layer: all three activations vs the jnp oracle,
native gateless lowering, d_model/d_expert tiling, capacity-row masking,
gradients, and the single-device model paths under use_kernels on/off."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MoEConfig
from repro.core import gating
from repro.kernels import ops, ref
from repro.kernels.streamed_moe import streamed_moe_kernel
from repro.models import moe as moe_mod


def _shapes(E=3, C=37, d=32, m=24, key=0):
    ks = jax.random.split(jax.random.PRNGKey(key), 4)
    xe = jax.random.normal(ks[0], (E, C, d), jnp.float32)
    wg = jax.random.normal(ks[1], (E, d, m), jnp.float32) * 0.1
    wu = jax.random.normal(ks[2], (E, d, m), jnp.float32) * 0.1
    wd = jax.random.normal(ks[3], (E, m, d), jnp.float32) * 0.1
    return xe, wg, wu, wd


@pytest.mark.parametrize("act", ["swiglu", "relu2", "gelu"])
def test_kernel_matches_ref_all_activations(act):
    """Satellite: gateless activations pass w_g=None natively."""
    xe, wg, wu, wd = _shapes()
    wg = wg if act == "swiglu" else None
    got = streamed_moe_kernel(xe, wg, wu, wd, activation=act, token_tile=16,
                              interpret=True)
    want = ref.streamed_moe_ref(xe, wg, wu, wd, act)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("act", ["swiglu", "relu2", "gelu"])
@pytest.mark.parametrize("dm_tile,de_tile", [(8, 8), (16, 12), (32, 24)])
def test_kernel_tiled_matches_ref(act, dm_tile, de_tile):
    """Micro-slices larger than one VMEM block lower via d_model/m tiling;
    C=37 with token_tile=16 also exercises padded-row masking."""
    xe, wg, wu, wd = _shapes()
    wg = wg if act == "swiglu" else None
    got = streamed_moe_kernel(xe, wg, wu, wd, activation=act, token_tile=16,
                              dmodel_tile=dm_tile, dexpert_tile=de_tile,
                              interpret=True)
    want = ref.streamed_moe_ref(xe, wg, wu, wd, act)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_gateless_ships_no_placeholder_operand():
    """relu2/gelu must not lower a w_gate operand at all (the old kernel
    shipped w_u twice as a placeholder, doubling HBM→VMEM traffic)."""
    xe, _, wu, wd = _shapes()
    jaxpr = jax.make_jaxpr(
        lambda xe, wu, wd: streamed_moe_kernel(
            xe, None, wu, wd, activation="gelu", interpret=True))(xe, wu, wd)
    calls = [e for e in jaxpr.eqns if e.primitive.name == "pallas_call"]
    assert calls, "expected a pallas_call in the jaxpr"
    assert len(calls[0].invars) == 3          # xe, w_u, w_d — no placeholder


def test_swiglu_requires_gate():
    xe, _, wu, wd = _shapes()
    with pytest.raises(ValueError):
        streamed_moe_kernel(xe, None, wu, wd, activation="swiglu")
    with pytest.raises(ValueError):
        ref.streamed_moe_ref(xe, None, wu, wd, "swiglu")


@pytest.mark.parametrize("act", ["swiglu", "gelu"])
def test_ops_dispatch_parity_and_grads(act):
    """ops.streamed_moe: kernel branch (fwd + custom-VJP bwd) matches the
    use_kernels(False) oracle branch."""
    xe, wg, wu, wd = _shapes()
    wg = wg if act == "swiglu" else None

    def loss(wu, wg):
        return jnp.sum(ops.streamed_moe(xe, wg, wu, wd, act) ** 2)

    with ops.use_kernels(True):
        y_k = ops.streamed_moe(xe, wg, wu, wd, act, interpret=True)
        g_k = jax.grad(loss)(wu, wg)
    with ops.use_kernels(False):
        y_r = ops.streamed_moe(xe, wg, wu, wd, act)
        g_r = jax.grad(loss)(wu, wg)
    np.testing.assert_allclose(y_k, y_r, rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(g_k, g_r, rtol=2e-4, atol=2e-5)


@pytest.mark.parametrize("act", ["swiglu", "relu2"])
@pytest.mark.parametrize("sorted_dispatch", [False, True])
def test_moe_capacity_kernel_parity(act, sorted_dispatch):
    """Single-device capacity path flows through the dispatch layer and is
    bit-compatible (within fp32 tolerance) across kernel on/off."""
    moe = MoEConfig(num_experts=4, top_k=2, d_expert=24, capacity_factor=2.0)
    params = moe_mod.moe_init(jax.random.PRNGKey(0), 16, moe, act, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (21, 16), jnp.float32)
    r = gating.route(params["router"], x, top_k=moe.top_k)
    ctx = moe_mod.use_sorted_dispatch(sorted_dispatch)
    with ctx, ops.use_kernels(True):
        y_k = moe_mod.moe_capacity(params, x, r, moe, act)
    ctx = moe_mod.use_sorted_dispatch(sorted_dispatch)
    with ctx, ops.use_kernels(False):
        y_r = moe_mod.moe_capacity(params, x, r, moe, act)
    np.testing.assert_allclose(y_k, y_r, rtol=2e-5, atol=2e-5)


def test_fse_dp_single_device_kernel_parity():
    """fse_dp strategy without a mesh (P=1 capacity fallback), kernels on/off."""
    from repro.core import strategy
    moe = MoEConfig(num_experts=4, top_k=2, d_expert=32, capacity_factor=2.0)
    params = moe_mod.moe_init(jax.random.PRNGKey(2), 16, moe, "swiglu",
                              jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 8, 16), jnp.float32)
    with ops.use_kernels(True):
        y_k, aux_k = strategy.execute("fse_dp", params, x, moe, "swiglu")
    with ops.use_kernels(False):
        y_r, aux_r = strategy.execute("fse_dp", params, x, moe, "swiglu")
    np.testing.assert_allclose(y_k, y_r, rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(aux_k, aux_r, rtol=1e-6)


def test_kernel_micro_slice_sum_order_invariant():
    """Σ over permuted d_expert micro-slices == whole-expert FFN through the
    new tiled kernel (FSE-DP virtualization property)."""
    E, C, d, de, M = 2, 19, 32, 48, 4
    xe, wg, wu, wd = _shapes(E=E, C=C, d=d, m=de, key=7)
    full = ref.streamed_moe_ref(xe, wg, wu, wd, "swiglu")
    mic = de // M
    parts = [streamed_moe_kernel(
        xe, wg[..., i * mic:(i + 1) * mic], wu[..., i * mic:(i + 1) * mic],
        wd[:, i * mic:(i + 1) * mic, :], activation="swiglu", token_tile=8,
        dmodel_tile=16, interpret=True)
        for i in np.random.default_rng(0).permutation(M)]
    np.testing.assert_allclose(sum(parts), full, rtol=3e-5, atol=3e-5)
