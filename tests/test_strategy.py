"""Execution-strategy registry + ExecutionSpec: JSON round-trip, plan
identity, forced-spec bit-parity with the pre-refactor entry points for
all five families, cross-family auto planner vs the chiplet simulator,
and the no-direct-calls acceptance grep."""
import json
import os
import re
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MoEConfig
from repro.core import autotune, gating
from repro.core import strategy as strat
from repro.core.strategy import (FAMILIES, FAMILY_SWEEP, ExecutionSpec,
                                 StrategyContext)
from repro.models import moe as moe_mod

D_MODEL = 16


def _setup(E=8, k=2, de=32, cf=4.0, act="swiglu", shared=0):
    moe = MoEConfig(num_experts=E, top_k=k, d_expert=de, capacity_factor=cf,
                    num_shared_experts=shared)
    params = moe_mod.moe_init(jax.random.PRNGKey(0), D_MODEL, moe, act,
                              jnp.float32)
    return moe, params


# ---------------------------------------------------------------------------
# ExecutionSpec: round-trip + resolution
# ---------------------------------------------------------------------------


def test_spec_json_roundtrip_identical_plan():
    spec = ExecutionSpec(strategy="auto", prefill="fse_dp", decode="ep",
                         layer_overrides={0: "fse_dp", 3: "tp"},
                         autotune="analytic", sorted_dispatch=True)
    spec2 = ExecutionSpec.from_json(spec.to_json())
    assert spec2 == spec
    # layer override keys survive the str-keyed JSON mapping
    assert dict(spec2.layer_overrides) == {0: "fse_dp", 3: "tp"}
    # the round-tripped spec plans identically for every call site
    moe, _ = _setup()
    ctx = StrategyContext(B=2, S=16, d_model=D_MODEL, moe=moe,
                          activation="swiglu", P=4)
    for phase in (None, "prefill", "decode"):
        for layer in (None, 0, 1, 3):
            n1 = spec.resolve(phase=phase, layer=layer)
            n2 = spec2.resolve(phase=phase, layer=layer)
            assert n1 == n2
            assert strat.get_strategy(n1).plan(ctx) == \
                strat.get_strategy(n2).plan(ctx)


def test_spec_resolution_precedence():
    spec = ExecutionSpec(strategy="capacity", decode="ep",
                         layer_overrides={1: "tp"})
    assert spec.resolve() == "capacity"
    assert spec.resolve(phase="decode") == "ep"
    assert spec.resolve(phase="decode", layer=1) == "tp"
    assert spec.resolve(phase="prefill", layer=0) == "capacity"
    assert spec.strategies_used() == ("capacity", "ep", "tp")
    with pytest.raises(ValueError):
        spec.resolve(phase="warmup")


def test_spec_coerce_and_validation():
    assert ExecutionSpec.coerce(None, default="dense").strategy == "dense"
    assert ExecutionSpec.coerce("ep").strategy == "ep"
    assert ExecutionSpec.coerce({"strategy": "tp"}).strategy == "tp"
    # a partial dict keeps the caller's configured default strategy
    partial = ExecutionSpec.coerce({"autotune": "off"}, default="fse_dp")
    assert partial.strategy == "fse_dp" and partial.autotune == "off"
    spec = ExecutionSpec.coerce("fse_dp")
    assert ExecutionSpec.coerce(spec) is spec
    with pytest.raises(ValueError):
        ExecutionSpec(strategy="auto", autotune="turbo")
    with pytest.raises(ValueError):
        ExecutionSpec.from_dict({"strategy": "auto", "impl": "x"})
    with pytest.raises(KeyError):
        ExecutionSpec(strategy="warp_drive").validate()


def test_registry_contents():
    for name in ("fse_dp", "ep", "tp", "capacity", "dense", "auto"):
        s = strat.get_strategy(name)
        assert s.name == name
        assert isinstance(s, strat.MoEStrategy)
    with pytest.raises(KeyError):
        strat.get_strategy("nope")


# ---------------------------------------------------------------------------
# forced-spec execution == the pre-refactor entry points (bit-identical)
# ---------------------------------------------------------------------------


def _old_single_device(params, x, moe, act, impl):
    """The pre-refactor moe_block body for one impl (single device)."""
    shape = x.shape
    if x.ndim == 2:
        x = x[None]
    x2d = x.reshape(-1, shape[-1])
    routing = gating.route(params["router"], x2d, top_k=moe.top_k)
    if impl == "dense":
        y = moe_mod.moe_dense(params, x2d, routing, act)
    else:
        y = moe_mod.moe_capacity(params, x2d, routing, moe, act)
    y = y.reshape(x.shape)
    aux = gating.aux_load_balance_loss(routing, moe.num_experts)
    if moe.num_shared_experts:
        from repro.models.mlp import ffn
        y = y + ffn(params["shared"], x, act)
    return y.reshape(shape), aux


@pytest.mark.parametrize("family", ["dense", "capacity", "fse_dp", "ep",
                                    "tp"])
def test_forced_spec_bit_identical(family):
    """moe_block(spec=<family>) reproduces the old entry point exactly.

    Single device: fse_dp / ep / tp all take their P=1 capacity
    fallback, which the deprecated ``*_moe_3d`` shims still expose."""
    moe, params = _setup(shared=1)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 10, D_MODEL),
                          jnp.float32)
    y, aux = moe_mod.moe_block(params, x, moe, "swiglu", spec=family,
                               return_aux=True)
    if family in ("dense", "capacity"):
        y_ref, aux_ref = _old_single_device(params, x, moe, "swiglu", family)
    else:
        from repro.core import baselines, fse_dp
        old = {"fse_dp": fse_dp.fse_dp_moe_3d, "ep": baselines.ep_moe_3d,
               "tp": baselines.tp_moe_3d}[family]
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            y_ref, aux_ref = old(params, x, moe, "swiglu")
        from repro.models.mlp import ffn
        y_ref = y_ref + ffn(params["shared"], x, "swiglu")
    assert np.array_equal(np.asarray(y), np.asarray(y_ref)), family
    assert np.array_equal(np.asarray(aux), np.asarray(aux_ref)), family


def test_auto_single_device_equals_capacity():
    moe, params = _setup()
    x = jax.random.normal(jax.random.PRNGKey(4), (3, 4, D_MODEL), jnp.float32)
    y_auto = moe_mod.moe_block(params, x, moe, "swiglu", spec="auto")
    y_cap = moe_mod.moe_block(params, x, moe, "swiglu", spec="capacity")
    assert np.array_equal(np.asarray(y_auto), np.asarray(y_cap))


def test_deprecated_shims_warn_once(monkeypatch):
    from repro.core import baselines, fse_dp
    moe, params = _setup()
    x = jax.random.normal(jax.random.PRNGKey(5), (1, 4, D_MODEL), jnp.float32)
    monkeypatch.setattr(strat, "_ENTRY_WARNED", set())
    for fn in (fse_dp.fse_dp_moe_3d, baselines.ep_moe_3d,
               baselines.tp_moe_3d):
        with pytest.warns(DeprecationWarning):
            fn(params, x, moe, "swiglu")
        with warnings.catch_warnings():
            warnings.simplefilter("error")      # second call is silent
            fn(params, x, moe, "swiglu")


# ---------------------------------------------------------------------------
# spec-scoped toggles
# ---------------------------------------------------------------------------


def test_spec_scopes_kernels_and_dispatch():
    moe, params = _setup()
    x = jax.random.normal(jax.random.PRNGKey(6), (2, 8, D_MODEL), jnp.float32)
    y_ref = moe_mod.moe_block(
        params, x, moe, "swiglu",
        spec=ExecutionSpec(strategy="capacity", use_kernels=False))
    from repro.kernels import ops as kops
    with kops.use_kernels(False):
        y_plain = moe_mod.moe_block(params, x, moe, "swiglu", spec="capacity")
    assert np.array_equal(np.asarray(y_ref), np.asarray(y_plain))
    # sorted dispatch through the spec == the explicit context toggle
    y_sorted = moe_mod.moe_block(
        params, x, moe, "swiglu",
        spec=ExecutionSpec(strategy="capacity", sorted_dispatch=True))
    with moe_mod.use_sorted_dispatch(True):
        y_ctx = moe_mod.moe_block(params, x, moe, "swiglu", spec="capacity")
    assert np.array_equal(np.asarray(y_sorted), np.asarray(y_ctx))


def test_spec_autotune_level_scoped():
    spec = ExecutionSpec(strategy="capacity", autotune="off")
    with spec.scope():
        assert autotune.autotune_level() == "off"


# ---------------------------------------------------------------------------
# cross-family auto planner vs the chiplet simulator (acceptance gate)
# ---------------------------------------------------------------------------


def _hw(P):
    from repro.sim.hardware import scaled
    return {2: scaled(1, 2), 4: scaled(2, 2), 8: scaled(2, 4)}[P]


def test_family_ranking_agrees_with_simulator():
    from repro.sim import modes as sim_modes
    from repro.sim.hardware import ModelSpec
    assert len(FAMILY_SWEEP) >= 12
    agree, rows = 0, []
    for (B, S, E, de, P) in FAMILY_SWEEP:
        hw = _hw(P)
        profile = autotune.HardwareProfile.from_chiplet(hw)
        moe = MoEConfig(num_experts=E, top_k=2, d_expert=de)
        costs = strat.family_costs(B, S, 512, moe, "swiglu", P,
                                   profile=profile)
        chosen = strat.pick_family(costs)
        sim = sim_modes.rank_families(hw, ModelSpec("s", 512, de, E, 2),
                                      B * S, B=B, S=S)
        best = min((f for f in FAMILIES if f in sim), key=lambda f: sim[f])
        agree += chosen == best
        rows.append((B, S, E, de, P, chosen, best))
    frac = agree / len(FAMILY_SWEEP)
    assert frac >= 0.8, f"family rank agreement {frac:.2f} < 0.8: {rows}"


def test_family_sweep_exercises_all_families():
    """The referee must not be degenerate: each family wins somewhere.

    On homogeneous hardware only the BASE_FAMILIES race (``hybrid``
    needs a near-memory tier — its own sweep lives in
    tests/test_hybrid.py)."""
    from repro.sim import modes as sim_modes
    from repro.sim.hardware import ModelSpec
    winners = set()
    for (B, S, E, de, P) in FAMILY_SWEEP:
        sim = sim_modes.rank_families(_hw(P), ModelSpec("s", 512, de, E, 2),
                                      B * S, B=B, S=S)
        assert "hybrid" not in sim          # no NDP tier on this hardware
        winners.add(min((f for f in FAMILIES if f in sim),
                        key=lambda f: sim[f]))
    assert winners == set(strat.BASE_FAMILIES)


def test_plan_family_off_level_routes_through_registry():
    moe, _ = _setup()
    plan = strat.plan_family(4, 16, 512, moe, "swiglu", 4, level="off")
    assert plan.family == "fse_dp" and plan.source == "fallback"
    assert plan.mode == "stream"            # the legacy static heuristic
    # P=1 resolves to the capacity fallback family
    plan1 = strat.plan_family(4, 16, 512, moe, "swiglu", 1)
    assert plan1.family == "capacity"


def test_auto_plan_carries_family_breakdown():
    moe = MoEConfig(num_experts=16, top_k=2, d_expert=512)
    profile = autotune.HardwareProfile.from_chiplet(_hw(4))
    ctx = StrategyContext(B=8, S=1, d_model=512, moe=moe,
                          activation="swiglu", P=4, profile=profile)
    plan = strat.get_strategy("auto").plan(ctx)
    assert plan.family in FAMILIES
    assert plan.family in dict(plan.per_mode_s)   # cost breakdown attached
    assert plan.predicted_s > 0


def test_ep_feasibility_rules():
    assert strat.ep_feasible(B=8, S=1, E=16, P=4)     # batch-shardable
    assert strat.ep_feasible(B=1, S=8, E=16, P=4)     # seq-shardable
    assert not strat.ep_feasible(B=3, S=1, E=16, P=4)  # neither divides
    assert not strat.ep_feasible(B=8, S=8, E=12, P=8)  # experts don't split
    assert not strat.ep_feasible(B=8, S=8, E=16, P=1)  # no model axis


# ---------------------------------------------------------------------------
# acceptance grep: the five families are reachable only via the registry
# ---------------------------------------------------------------------------


def test_no_direct_moe3d_calls_outside_shims():
    """`grep` gate from the issue: no ``*_moe_3d(`` call sites outside the
    one-line deprecation shims (defs + shim bodies in core/fse_dp.py and
    core/baselines.py; this test calls them via getattr only)."""
    root = os.path.join(os.path.dirname(__file__), "..")
    allowed = {os.path.normpath(p) for p in
               ("src/repro/core/fse_dp.py", "src/repro/core/baselines.py",
                "src/repro/core/__init__.py", "tests/test_strategy.py")}
    pat = re.compile(r"\b(?:fse_dp|ep|tp)_moe_3d\s*\(")
    offenders = []
    for sub in ("src", "tests", "benchmarks", "examples"):
        for dirpath, _, files in os.walk(os.path.join(root, sub)):
            for fn in files:
                if not fn.endswith(".py"):
                    continue
                path = os.path.join(dirpath, fn)
                rel = os.path.normpath(os.path.relpath(path, root))
                if rel in allowed:
                    continue
                with open(path) as f:
                    for i, line in enumerate(f, 1):
                        if pat.search(line):
                            offenders.append(f"{rel}:{i}: {line.strip()}")
    assert not offenders, "direct *_moe_3d calls outside the shims:\n" + \
        "\n".join(offenders)
