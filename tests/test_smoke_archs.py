"""Per-architecture smoke tests (deliverable f): reduced config of the
same family, one forward/train step + one decode step on CPU, asserting
output shapes and finiteness."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED_ARCHS, reduced_config
from repro.models import api

B, S = 2, 32


def _batch(cfg, key):
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
             "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.frontend and cfg.frontend.kind == "vision":
        batch["prefix_embeds"] = jnp.ones(
            (B, cfg.frontend.num_prefix_tokens, cfg.d_model), jnp.dtype(cfg.dtype))
    if cfg.is_encoder_decoder:
        batch["frames"] = jnp.ones((B, S, cfg.d_model), jnp.dtype(cfg.dtype))
    return batch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_forward_and_loss(arch):
    cfg = reduced_config(arch)
    key = jax.random.PRNGKey(0)
    params = api.init_params(key, cfg)
    loss, metrics = api.loss_fn(params, _batch(cfg, key), cfg)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), arch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_train_step(arch):
    """One gradient step: params change, grads finite."""
    cfg = reduced_config(arch).replace(dtype="float32")
    key = jax.random.PRNGKey(0)
    params = api.init_params(key, cfg)
    batch = _batch(cfg, key)
    grads = jax.grad(lambda p: api.loss_fn(p, batch, cfg)[0])(params)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g)) for g in jax.tree.leaves(grads)))
    assert bool(jnp.isfinite(gn)) and float(gn) > 0, arch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_decode_step(arch):
    cfg = reduced_config(arch)
    key = jax.random.PRNGKey(0)
    params = api.init_params(key, cfg)
    caches = api.init_decode_caches(params, cfg, B, S, memory_len=16)
    logits, new_caches = api.decode_fn(params, jnp.zeros((B, 1), jnp.int32),
                                       caches, jnp.full((B,), 3, jnp.int32), cfg)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32)))), arch
    assert jax.tree.structure(new_caches) == jax.tree.structure(caches)
