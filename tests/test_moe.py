"""MoE block: routing properties (hypothesis), dense == per-token loop
oracle, capacity semantics, shared experts."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.configs.base import MoEConfig
from repro.core import gating
from repro.models import moe as moe_mod


def _setup(E=8, k=2, d=16, de=32, cf=4.0, act="swiglu", shared=0):
    moe = MoEConfig(num_experts=E, top_k=k, d_expert=de, capacity_factor=cf,
                    num_shared_experts=shared)
    params = moe_mod.moe_init(jax.random.PRNGKey(0), d, moe, act, jnp.float32)
    return moe, params


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 40), st.integers(2, 16), st.integers(1, 4))
def test_routing_properties(T, E, k):
    k = min(k, E)
    d = 8
    p = gating.router_init(jax.random.PRNGKey(E * 100 + k), d, E, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(T), (T, d), jnp.float32)
    r = gating.route(p, x, top_k=k)
    assert r.indices.shape == (T, k)
    # weights renormalized
    np.testing.assert_allclose(np.asarray(r.weights).sum(-1), 1.0, rtol=1e-4)
    # combine rows sum to 1 and have exactly k nonzeros
    comb = np.asarray(r.combine)
    np.testing.assert_allclose(comb.sum(-1), 1.0, rtol=1e-4)
    assert ((comb > 0).sum(-1) <= k).all()
    # full probs are a distribution
    np.testing.assert_allclose(np.asarray(r.probs).sum(-1), 1.0, rtol=1e-4)


def test_dense_matches_per_token_loop():
    moe, params = _setup()
    x = jax.random.normal(jax.random.PRNGKey(1), (10, 16), jnp.float32)
    r = gating.route(params["router"], x, top_k=moe.top_k)
    y = moe_mod.moe_dense(params, x, r, "swiglu")

    # per-token oracle
    idx = np.asarray(r.indices)
    w = np.asarray(r.weights)
    y_ref = np.zeros_like(np.asarray(y))
    for t in range(10):
        for j in range(moe.top_k):
            e = idx[t, j]
            xe = x[t][None]
            h = jax.nn.silu(xe @ params["w_gate"][e]) * (xe @ params["w_up"][e])
            y_ref[t] += w[t, j] * np.asarray(h @ params["w_down"][e])[0]
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-4, atol=2e-4)


def test_capacity_matches_dense_with_ample_capacity():
    moe, params = _setup(cf=8.0 / 2.0)        # C >= T: nothing drops
    x = jax.random.normal(jax.random.PRNGKey(2), (12, 16), jnp.float32)
    r = gating.route(params["router"], x, top_k=moe.top_k)
    y_c = moe_mod.moe_capacity(params, x, r, moe, "swiglu")
    y_d = moe_mod.moe_dense(params, x, r, "swiglu")
    np.testing.assert_allclose(y_c, y_d, rtol=2e-4, atol=2e-4)


def test_capacity_drops_overflow():
    """With capacity 1 token per expert, overflow tokens contribute 0."""
    moe, params = _setup(E=2, k=1, cf=2.0 / 16.0)   # C = 1 for T=16
    x = jnp.ones((16, 16), jnp.float32)             # identical tokens -> same expert
    r = gating.route(params["router"], x, top_k=1)
    y = moe_mod.moe_capacity(params, x, r, moe, "swiglu")
    nz = np.abs(np.asarray(y)).sum(-1) > 1e-9
    assert nz.sum() == 1                            # only the first survives


def test_aux_loss_uniform_vs_skewed():
    r_uniform = gating.Routing(
        indices=jnp.arange(8).reshape(8, 1) % 4,
        weights=jnp.ones((8, 1)),
        probs=jnp.full((8, 4), 0.25),
        combine=jax.nn.one_hot(jnp.arange(8) % 4, 4))
    r_skew = gating.Routing(
        indices=jnp.zeros((8, 1), jnp.int32),
        weights=jnp.ones((8, 1)),
        probs=jnp.eye(4)[jnp.zeros(8, jnp.int32)],
        combine=jax.nn.one_hot(jnp.zeros(8, jnp.int32), 4))
    assert float(gating.aux_load_balance_loss(r_skew, 4)) > \
        float(gating.aux_load_balance_loss(r_uniform, 4))


def test_shared_experts_added():
    moe, params = _setup(shared=1)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 4, 16), jnp.float32)
    y = moe_mod.moe_block(params, x, moe, "swiglu", impl="dense")
    params2 = dict(params)
    params2["shared"] = jax.tree.map(jnp.zeros_like, params["shared"])
    y2 = moe_mod.moe_block(params2, x, moe, "swiglu", impl="dense")
    assert not np.allclose(np.asarray(y), np.asarray(y2))


@pytest.mark.parametrize("impl", ["dense", "capacity"])
@pytest.mark.parametrize("shared", [0, 1])
def test_moe_block_2d_matches_3d(impl, shared):
    """(T,d) input == the (1,T,d) path, exact values and shape — the
    regression test for the old double-reshape around the shared-expert
    add (2-D x reshaped to 3-D and back must change nothing)."""
    moe, params = _setup(shared=shared)
    x2d = jax.random.normal(jax.random.PRNGKey(7), (12, 16), jnp.float32)
    y2d = moe_mod.moe_block(params, x2d, moe, "swiglu", impl=impl)
    y3d = moe_mod.moe_block(params, x2d[None], moe, "swiglu", impl=impl)
    assert y2d.shape == x2d.shape
    np.testing.assert_array_equal(np.asarray(y2d), np.asarray(y3d)[0])


def test_expert_token_counts():
    moe, params = _setup(E=4, k=2)
    x = jax.random.normal(jax.random.PRNGKey(4), (20, 16), jnp.float32)
    r = gating.route(params["router"], x, top_k=2)
    counts = np.asarray(gating.expert_token_counts(r))
    assert counts.sum() == 20 * 2
