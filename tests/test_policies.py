"""Paired-load ordering + Algorithm 2 token-buffering semantics."""
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core.policies import (QoSState, TokenBufferPolicy, expert_pairs,
                                 paired_load_order)


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(0, 100), min_size=1, max_size=32))
def test_paired_order_is_permutation(counts):
    order = paired_load_order(counts)
    assert sorted(order) == list(range(len(counts)))


def test_paired_order_interleaves_hot_cold():
    counts = [100, 1, 50, 2, 25, 3]
    order = paired_load_order(counts)
    # first two entries: hottest then coldest active
    assert counts[order[0]] == 100
    assert counts[order[1]] == 1
    assert counts[order[2]] == 50
    assert counts[order[3]] == 2


def test_idle_experts_last():
    counts = [5, 0, 3, 0]
    order = paired_load_order(counts)
    assert set(order[-2:]) == {1, 3}


def test_expert_pairs():
    pairs = expert_pairs([10, 1, 5, 2, 0])
    assert pairs[0] == (0, 1)      # hottest with coldest
    assert pairs[1] == (2, 3)


# ---------------------------------------------------------------------------
# paired-load edge cases (the schedule stage depends on these exactly)
# ---------------------------------------------------------------------------


def test_paired_order_all_zero_counts():
    """No active experts: the order is still a permutation (all idle),
    and there is nothing to pair."""
    counts = [0, 0, 0, 0]
    order = paired_load_order(counts)
    assert sorted(order) == [0, 1, 2, 3]
    assert expert_pairs(counts) == []


def test_paired_order_single_expert():
    assert paired_load_order([7]) == [0]
    assert expert_pairs([7]) == [(0, None)]
    assert paired_load_order([0]) == [0]
    assert expert_pairs([0]) == []


def test_paired_order_odd_active_count():
    """Odd number of active experts: the middle expert stands alone and
    pairs with None."""
    counts = [9, 4, 1]
    assert paired_load_order(counts) == [0, 2, 1]
    pairs = expert_pairs(counts)
    assert pairs == [(0, 2), (1, None)]


def test_paired_order_tied_loads_deterministic():
    """Ties resolve by stable index order — the trajectory must be
    deterministic so static/dynamic comparisons are reproducible."""
    counts = [5, 5, 5, 5]
    assert paired_load_order(counts) == [0, 3, 1, 2]
    assert paired_load_order(counts) == paired_load_order(list(counts))
    assert expert_pairs(counts) == [(0, 3), (1, 2)]


def test_paired_order_numpy_and_list_inputs_agree():
    counts = [3, 0, 8, 0, 1]
    assert paired_load_order(np.asarray(counts)) == paired_load_order(counts)
    # idle experts trail the active ones
    assert paired_load_order(counts)[-2:] in ([1, 3], [3, 1])


class TestAlgorithm2:
    def test_timer_grants_after_threshold(self):
        p = TokenBufferPolicy(theta_min=4, n_threshold=3)
        for _ in range(2):
            p.on_forward_pass("r")
        assert p.state("r").timer == 0
        p.on_forward_pass("r")
        assert p.state("r").timer == 1
        assert p.state("r").fw_count == 0          # reset (line 4)

    def test_defer_requires_cold_and_credit(self):
        p = TokenBufferPolicy(theta_min=4, n_threshold=1)
        counts = [10, 2, 8]
        # no credit yet
        assert not p.should_defer("r", [1], counts)
        p.on_forward_pass("r")
        # credit + cold expert (n_e=2 < 4) -> defer + decrement (lines 6-8)
        assert p.should_defer("r", [1], counts)
        assert p.state("r").timer == 0
        # credit exhausted
        assert not p.should_defer("r", [1], counts)

    def test_hot_experts_never_defer(self):
        p = TokenBufferPolicy(theta_min=4, n_threshold=1)
        p.on_forward_pass("r")
        assert not p.should_defer("r", [0, 2], [10, 2, 8])
        assert p.state("r").timer == 1             # credit kept

    def test_from_slack(self):
        p = TokenBufferPolicy.from_slack(0.10)
        assert p.n_threshold == 10
        p = TokenBufferPolicy.from_slack(0.30)
        assert p.n_threshold == 4
        p0 = TokenBufferPolicy.from_slack(0.0)
        p0.on_forward_pass("r")
        assert p0.state("r").timer == 0            # never grants

    @settings(max_examples=25, deadline=None)
    @given(st.integers(1, 20), st.integers(1, 200))
    def test_deferral_rate_bounded_by_slack(self, n_threshold, passes):
        """#deferrals <= #passes / n_threshold + 1 (the QoS contract)."""
        p = TokenBufferPolicy(theta_min=10, n_threshold=n_threshold)
        defers = 0
        for _ in range(passes):
            p.on_forward_pass("r")
            if p.should_defer("r", [0], [1]):      # always-cold expert
                defers += 1
        assert defers <= passes // n_threshold + 1
