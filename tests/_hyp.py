"""Import shim for the optional ``hypothesis`` dependency.

Property-based cases run normally when hypothesis is installed; without
it they are collected and skipped, so the deterministic tests in the
same modules always run (the seed suite used to die at collection).

Usage in test modules::

    from _hyp import given, settings, st
"""
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # fallback shim — mark property tests as skipped
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)
        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco

    class _Strategies:
        """Stub namespace: every strategy constructor returns None (the
        values are never drawn because @given skips the test)."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _Strategies()
