"""End-to-end driver: train a ~100M-param granite-style MoE for a few
hundred steps on the synthetic pipeline, with checkpointing + resume.

  PYTHONPATH=src python examples/train_moe_100m.py [--steps 300]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import get_config
from repro.configs.base import MoEConfig
from repro.data import DataConfig
from repro.training import TrainConfig, train


def build_100m_config():
    """granite-family MoE scaled to ~100M params (same 32e/top-8 shape)."""
    base = get_config("granite-moe-1b-a400m")
    cfg = base.replace(
        name="granite-moe-100m", num_layers=6, d_model=512, num_heads=8,
        num_kv_heads=4, head_dim=64, d_ff=256, vocab_size=8192,
        dtype="float32",
        moe=MoEConfig(num_experts=16, top_k=4, d_expert=256, impl="capacity"))
    print(f"config: {cfg.name} params={cfg.param_count()/1e6:.1f}M "
          f"active={cfg.active_param_count()/1e6:.1f}M")
    return cfg


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_100m_ckpt")
    args = ap.parse_args()

    cfg = build_100m_config()
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                      global_batch=args.batch, seed=17)
    tcfg = TrainConfig(lr=1e-3, total_steps=args.steps,
                       warmup=args.steps // 10, ckpt_dir=args.ckpt_dir,
                       ckpt_every=max(50, args.steps // 4),
                       log_every=max(1, args.steps // 30))

    def log(step, m):
        print(f"step {step:5d}  loss {m['loss']:.4f}  ce {m['ce']:.4f}  "
              f"aux {m['aux']:.3f}  gnorm {m['grad_norm']:.2f}")

    res = train(cfg, dcfg, tcfg, seed=0, hooks=log)
    first, last = res.losses[0][1], res.losses[-1][1]
    print(f"\nloss {first:.3f} -> {last:.3f} over {res.final_step} steps "
          f"({res.wall_time:.0f}s; resumed_from={res.resumed_from}; "
          f"checkpoints in {args.ckpt_dir})")
    assert last < first, "training must improve the loss"


if __name__ == "__main__":
    main()
