"""Paper-reproduction demo: run the chiplet simulator across all four
Table-I models and print the headline claims (speedup band + memory
saving), like a miniature of §VI.

  PYTHONPATH=src python examples/expert_streaming_sim.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.sim import PROTOTYPE_2X2, PAPER_SPECS, iteration_workloads, simulate_layer


def main():
    hw = PROTOTYPE_2X2
    print(f"array: {hw.rows}x{hw.cols} chiplets, {hw.tops/1e12:.2f} TOPS/die, "
          f"D2D {hw.d2d_gbps/1e9:.0f} GB/s, DDR {hw.ddr_total/1e9:.1f} GB/s, "
          f"{hw.buffer_bytes/2**20:.0f} MB SRAM/die\n")
    speedups, savings = [], []
    print(f"{'model':14s}{'tokens':>7s}{'EP (us)':>10s}{'FSE-DP (us)':>12s}"
          f"{'speedup':>9s}{'EP mem':>9s}{'FSE mem':>9s}{'saving':>8s}")
    for mname, spec in PAPER_SPECS.items():
        for toks in (16, 64, 256):
            l_ep, l_fse, m_ep, m_fse = [], [], [], []
            for seed in range(3):
                wl = iteration_workloads(spec, tokens_per_iter=toks,
                                         num_chiplets=hw.num_chiplets,
                                         seed=seed)[0]
                rep = simulate_layer(hw, spec, wl, "ep")
                rfs = simulate_layer(hw, spec, wl, "fse_dp_paired")
                l_ep.append(rep.latency); l_fse.append(rfs.latency)
                m_ep.append(rep.peak_buffer_bytes); m_fse.append(rfs.peak_buffer_bytes)
            sp = np.mean(l_ep) / np.mean(l_fse)
            sv = 1 - np.mean(m_fse) / np.mean(m_ep)
            speedups.append(sp); savings.append(sv)
            print(f"{mname:14s}{toks:>7d}{np.mean(l_ep)*1e6:>10.0f}"
                  f"{np.mean(l_fse)*1e6:>12.0f}{sp:>8.2f}x"
                  f"{np.mean(m_ep)/2**20:>8.0f}M{np.mean(m_fse)/2**20:>8.0f}M"
                  f"{100*sv:>7.1f}%")
    print(f"\nspeedup over EP: {min(speedups):.2f}x .. {max(speedups):.2f}x "
          f"(paper: 1.22-2.00x vs its baselines)")
    print(f"on-chip memory saving: up to {100*max(savings):.1f}% "
          f"(paper: up to 78.8%)")


if __name__ == "__main__":
    main()
