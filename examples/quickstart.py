"""Quickstart: the paper's idea in 60 seconds on CPU.

1. builds a small MoE layer,
2. shows FSE-DP expert streaming == the dense oracle (order-invariant
   micro-slice partial sums),
3. runs one chiplet-simulator comparison (FSE-DP vs EP latency+memory).

  PYTHONPATH=src python examples/quickstart.py
"""
import os
import subprocess
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import MoEConfig
from repro.core import gating
from repro.kernels import ref
from repro.kernels.streamed_moe import streamed_moe_kernel
from repro.models import moe as moe_mod
from repro.sim import PROTOTYPE_2X2, PAPER_SPECS, iteration_workloads, simulate_layer


def main():
    print("== 1. micro-slice order invariance (the virtualization argument) ==")
    E, C, d, de, M = 4, 16, 32, 64, 4
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    xe = jax.random.normal(ks[0], (E, C, d), jnp.float32)
    wg = jax.random.normal(ks[1], (E, d, de)) * 0.1
    wu = jax.random.normal(ks[2], (E, d, de)) * 0.1
    wd = jax.random.normal(ks[3], (E, de, d)) * 0.1
    full = ref.streamed_moe_ref(xe, wg, wu, wd, "swiglu")
    mic = de // M
    order = np.random.default_rng(0).permutation(M)
    parts = sum(streamed_moe_kernel(xe, wg[..., i*mic:(i+1)*mic],
                                    wu[..., i*mic:(i+1)*mic],
                                    wd[:, i*mic:(i+1)*mic, :], activation="swiglu")
                for i in order)
    err = float(jnp.max(jnp.abs(parts - full)))
    print(f"   Σ(micro-slices in random order {list(order)}) vs whole expert: "
          f"max err = {err:.2e}  ✓ trajectory order is immaterial")

    print("== 2. FSE-DP distributed == dense oracle (8 fake devices) ==")
    script = os.path.join(os.path.dirname(__file__), "..", "tests",
                          "distributed_scripts", "fsedp_modes.py")
    env = dict(os.environ, XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"))
    out = subprocess.run([sys.executable, script], env=env, capture_output=True,
                         text=True, timeout=900)
    print("   " + out.stdout.strip().splitlines()[-1])

    print("== 3. chiplet simulator: FSE-DP vs EP (paper Table-I hardware) ==")
    hw = PROTOTYPE_2X2
    spec = PAPER_SPECS["qwen3-a3b"]
    wl = iteration_workloads(spec, tokens_per_iter=64,
                             num_chiplets=hw.num_chiplets, seed=0)[0]
    for strat in ("ep", "fse_dp_paired"):
        r = simulate_layer(hw, spec, wl, strat)
        print(f"   {strat:14s} latency={r.latency*1e6:8.0f}us  "
              f"package-mem={r.peak_buffer_bytes/2**20:6.1f}MB  "
              f"util={r.utilization:.3f}")


if __name__ == "__main__":
    main()
