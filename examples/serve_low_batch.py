"""Low-batch MoE serving with token buffering (the paper's target
scenario): batched requests through the layer-stepped engine, comparing
slack=0 vs slack>0 — identical outputs, fewer cold-expert loads.

  PYTHONPATH=src python examples/serve_low_batch.py
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import reduced_config
from repro.models import api
from repro.serving import Engine, ServeConfig


def run_engine(params, cfg, slack, prompts, n_threshold=None):
    eng = Engine(params, cfg, ServeConfig(max_batch=8, max_ctx=64,
                                          buffering_slack=slack, theta_min=3))
    if n_threshold:
        eng.policy.n_threshold = n_threshold
    rids = [eng.submit(p, max_new=12) for p in prompts]
    t0 = time.time()
    outs = eng.run()
    dt = time.time() - t0
    return eng, [outs[r] for r in rids], dt


def main():
    cfg = reduced_config("granite-moe-1b-a400m").replace(dtype="float32")
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab_size, size=rng.integers(3, 10)).tolist()
               for _ in range(6)]

    eng0, outs0, dt0 = run_engine(params, cfg, 0.0, prompts)
    eng1, outs1, dt1 = run_engine(params, cfg, 0.3, prompts, n_threshold=3)

    assert outs0 == outs1, "token buffering must not change outputs"
    print("outputs identical with and without token buffering ✓\n")
    hdr = f"{'':18s}{'iterations':>11s}{'deferrals':>10s}{'expert loads':>13s}{'loads saved':>12s}"
    print(hdr)
    for label, e in (("slack=0.0", eng0), ("slack=0.3", eng1)):
        s = e.stats
        print(f"{label:18s}{s['iterations']:>11d}{s['deferrals']:>10d}"
              f"{s['expert_loads']:>13d}{s['expert_loads_saved']:>12d}")
    saved = eng1.stats["expert_loads_saved"]
    total = eng0.stats["expert_loads"]
    print(f"\ncold-expert DDR fetches avoided: {saved}/{total} "
          f"({100*saved/max(total,1):.1f}%) at "
          f"{eng1.stats['iterations']-eng0.stats['iterations']} extra iterations "
          f"(the paper's QoS-for-efficiency trade)")
    # per-layer paired-load order from live routing stats
    t = eng1.trace[0]
    print(f"\nexample paired-load order (iter {t['iter']}, layer {t['layer']}): "
          f"{t['order'][:8]}... counts={t['counts'][t['order'][:8]].tolist()}")


if __name__ == "__main__":
    main()
