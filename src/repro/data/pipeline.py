"""Deterministic, resumable synthetic token pipeline.

Batches are a pure function of (seed, step) — restart at step k replays
exactly the same stream with zero state files (the fault-tolerance
contract: checkpoint stores only the step counter).

The synthetic "language" is a Zipf-unigram first-order Markov chain so
small LMs show a clearly decreasing loss (learnable bigram structure)
— used by the 100M-model example driver and the trainer tests.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    branching: int = 8        # Markov out-degree (lower = more learnable)


class SyntheticLM:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        V, B = cfg.vocab_size, cfg.branching
        # Zipf unigram over successors; each token has B possible successors
        self._succ = rng.integers(0, V, size=(V, B))
        p = 1.0 / np.arange(1, B + 1)
        self._succ_p = p / p.sum()

    def batch(self, step: int) -> dict:
        """{'tokens': (B,S) int32, 'labels': (B,S) int32} for this step."""
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step, 0xD47A))
        B, S, V = cfg.global_batch, cfg.seq_len, cfg.vocab_size
        toks = np.empty((B, S + 1), np.int32)
        toks[:, 0] = rng.integers(0, V, size=B)
        for t in range(S):
            choice = rng.choice(cfg.branching, size=B, p=self._succ_p)
            toks[:, t + 1] = self._succ[toks[:, t], choice]
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch(step)
            step += 1


def batch_for(cfg: DataConfig, step: int) -> dict:
    return SyntheticLM(cfg).batch(step)
