"""Step-level chiplet simulation of the three FSE-DP *SPMD* modes.

``sim.engine`` simulates the paper's trajectory scheduler at micro-slice
event granularity; this module simulates the three shard_map execution
modes of ``core.fse_dp`` (stream / index / slice) on the same
:class:`~repro.sim.hardware.HardwareConfig`, so the analytical cost
model in ``core.autotune`` has an independent, discrete referee:

* stream — tokens seq-sharded, weight micro-slices ``ppermute`` around
  the P-ring; per ring step each chiplet forwards the resident slice
  (async, port-serialized) while computing on it; DDR streams the local
  shard in micro-slice granules that the first pass consumes;
* index  — identical ring, but tokens are replicated: add the input
  all-gather and the fp32 output all-reduce (ring collectives);
* slice  — weights stationary; every chiplet routes ALL tokens against
  its d_expert/P slice, then the fp32 partial outputs are all-reduced.

The event structure (per-chiplet busy time, per-link transfer chains,
pipeline fill, DDR overlap) is deliberately *not* closed-form, so rank
agreement between ``autotune.mode_cost`` and ``simulate_mode`` is a
meaningful check rather than an identity.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, Optional

import numpy as np

from .hardware import HardwareConfig, ModelSpec


@dataclass(frozen=True)
class ModeResult:
    mode: str
    latency: float
    compute_s: float            # per-chiplet mean busy compute seconds
    ring_bytes: float           # per-chiplet ppermute traffic
    collective_s: float         # gather + psum time (index/slice extras)
    ddr_bytes: float


def _capacity(tokens: int, spec: ModelSpec, capacity_factor: float) -> int:
    from repro.configs.base import moe_capacity_rows
    return moe_capacity_rows(tokens, spec.top_k, spec.num_experts,
                             capacity_factor)


def _ring_hop_time(hw: HardwareConfig, src: int, nbytes: float) -> float:
    dst = (src + 1) % hw.num_chiplets
    hops = max(1, hw.hops(src, dst))
    return nbytes / hw.d2d_gbps + hops * hw.d2d_hop_latency


def _allreduce_time(hw: HardwareConfig, nbytes_per_chip: float) -> float:
    """Ring all-reduce: 2(P-1) steps of 1/P-sized chunks."""
    P = hw.num_chiplets
    if P <= 1:
        return 0.0
    chunk = nbytes_per_chip / P
    step = max(_ring_hop_time(hw, c, chunk) for c in range(P))
    return 2 * (P - 1) * step


def _allgather_time(hw: HardwareConfig, nbytes_per_chip: float) -> float:
    P = hw.num_chiplets
    if P <= 1:
        return 0.0
    chunk = nbytes_per_chip / P
    step = max(_ring_hop_time(hw, c, chunk) for c in range(P))
    return (P - 1) * step


def _load_rows(E: int, C: int, assignments: float, loads) -> tuple:
    """(effective expert rows, active expert count) — the discrete twin
    of ``core.autotune.load_rows``.  ``loads`` is a normalized
    per-expert share vector; ``None`` keeps the padded E*C model."""
    if loads is None:
        return float(E * C), E
    l = np.asarray(loads, np.float64)
    r = np.minimum(float(C), assignments * l)
    return float(r.sum()), max(1, int((r >= 0.5).sum()))


def simulate_mode(hw: HardwareConfig, spec: ModelSpec, mode: str,
                  tokens: int, *, micro_slices: int = 1,
                  capacity_factor: float = 1.25,
                  act_bytes: Optional[int] = None,
                  loads=None) -> ModeResult:
    """Latency of one MoE layer executed in one FSE-DP SPMD mode.

    ``tokens`` is the global token count of the iteration (B*S); tokens
    split uniformly over chiplets, matching the seq-sharded layout.
    ``loads`` (a normalized per-expert load vector) switches the expert
    terms from the padded-capacity model to the observed-gating model:
    rows scale with the actual per-expert assignments and idle experts
    skip their DDR weight stream — the discrete referee of the
    load-aware cost model (``core.autotune.mode_cost(load=...)``).
    """
    P = hw.num_chiplets
    E, d, de = spec.num_experts, spec.d_model, spec.d_expert
    wb = spec.bytes_per_param or hw.bytes_per_param
    ab = act_bytes if act_bytes is not None else hw.bytes_per_act
    de_loc = de / P
    n_mats = spec.n_mats

    if mode not in ("stream", "index", "slice"):
        raise ValueError(mode)

    # ---- per-chiplet routed capacity rows --------------------------------
    if mode in ("stream", "index"):
        T_loc = tokens / P
        C = _capacity(max(1, math.ceil(T_loc)), spec, capacity_factor)
    else:
        T_loc = tokens
        C = _capacity(max(1, tokens), spec, capacity_factor)
    rows, active = _load_rows(E, C, T_loc * spec.top_k, loads)

    # dispatch/combine one-hots + router, charged as compute on every chip
    dispatch_flops = 2.0 * T_loc * E * C * d * 2 + 2.0 * T_loc * d * E
    ddr_shard = n_mats * active * d * de_loc * wb     # local weight shard

    if mode == "slice":
        flops = 2.0 * n_mats * rows * d * de_loc + dispatch_flops
        t_comp = flops / hw.tops
        t_ddr = ddr_shard / (hw.ddr_total / P)
        t_gather = _allgather_time(hw, tokens * d * ab)
        t_psum = _allreduce_time(hw, tokens * d * 4)
        lat = t_gather + max(t_comp, t_ddr) + t_psum
        return ModeResult("slice", lat, t_comp, 0.0, t_gather + t_psum,
                          ddr_shard * P)

    # ---- stream/index: discrete ring of P steps x M micro-slices ---------
    M = max(1, min(micro_slices, int(de_loc) or 1))
    slice_de = de_loc / M
    slice_bytes = n_mats * active * d * slice_de * wb
    comp_step = (2.0 * n_mats * rows * d * slice_de
                 + dispatch_flops / (P * M)) / hw.tops

    # DDR streams the local shard micro-slice by micro-slice; slice m of
    # the first ring pass cannot start before its granule has landed
    ddr_rate = hw.ddr_total / P
    ddr_done = [(m + 1) * slice_bytes / ddr_rate for m in range(M)]

    busy = np.zeros(P)
    port_free = np.zeros(P)
    ring_bytes = 0.0
    for m in range(M):
        arrive = np.full(P, ddr_done[m])
        for s in range(P):
            send_done = np.zeros(P)
            for c in range(P):
                start = max(busy[c], arrive[c])
                if s < P - 1:        # forward first (async), then compute
                    t0 = max(start, port_free[c])
                    send_done[c] = t0 + _ring_hop_time(hw, c, slice_bytes)
                    port_free[c] = send_done[c]
                    ring_bytes += slice_bytes
                busy[c] = start + comp_step
            arrive = np.roll(send_done, 1)
    lat = float(busy.max())

    t_gather = t_psum = 0.0
    if mode == "index":
        t_gather = _allgather_time(hw, tokens * d * ab)
        t_psum = _allreduce_time(hw, tokens * d * 4)
        lat = t_gather + lat + t_psum

    return ModeResult(mode, lat, float(busy.mean()), ring_bytes / P,
                      t_gather + t_psum, ddr_shard * P)


def simulate_ep(hw: HardwareConfig, spec: ModelSpec, tokens: int, *,
                capacity_factor: float = 1.25,
                act_bytes: Optional[int] = None,
                loads=None) -> ModeResult:
    """Latency of one MoE layer under the EP baseline family
    (``core.baselines.moe_ep``): tokens stay sharded, every chiplet owns
    E/P full experts, dispatched rows all-to-all to the owner and back.

    The all-to-all is simulated as discrete port-serialized peer
    messages over the 2D mesh (per-source send chains with Manhattan
    hop latency), deliberately not the closed-form ``(P-1)/P`` bytes
    the cost model (``autotune.ep_cost``) uses — so cross-family rank
    agreement is a meaningful check, matching the stream/index ring.

    ``loads`` (a normalized per-expert load vector) switches the expert
    terms from the padded-capacity model to the observed-gating model:
    dispatch rows, expert compute, and the local weight-shard stream
    scale with the actual assignments (``None`` is bit-identical to the
    padded model).
    """
    P = hw.num_chiplets
    E, d, de = spec.num_experts, spec.d_model, spec.d_expert
    if E % P:
        raise ValueError(f"EP needs E % P == 0 (E={E}, P={P})")
    ab = act_bytes if act_bytes is not None else hw.bytes_per_act
    E_loc = E // P
    T_loc = tokens / P
    C = _capacity(max(1, math.ceil(T_loc)), spec, capacity_factor)
    rows, active = _load_rows(E, C, T_loc * spec.top_k, loads)

    # one a2a phase: each source sends (P-1) peer messages of its
    # per-destination dispatch rows (rows/E routed rows per expert, E_loc
    # experts per destination), serialized on the source's port
    msg = (rows / E) * E_loc * d * ab
    t_a2a = max(
        sum(msg / hw.d2d_gbps + hw.hops(src, (src + s) % P)
            * hw.d2d_hop_latency for s in range(1, P))
        for src in range(P))

    dispatch_flops = 2.0 * T_loc * E * C * d * 2 + 2.0 * T_loc * d * E
    flops = 2.0 * spec.n_mats * rows * d * de + dispatch_flops
    t_comp = flops / hw.tops
    ddr = spec.n_mats * (active / E) * E_loc * d * de \
        * (spec.bytes_per_param or hw.bytes_per_param)
    t_ddr = ddr / (hw.ddr_total / P)
    lat = t_a2a + max(t_comp, t_ddr) + t_a2a
    return ModeResult("ep", lat, t_comp, 0.0, 2 * t_a2a, ddr * P)


def simulate_hybrid(hw: HardwareConfig, spec: ModelSpec, tokens: int, *,
                    capacity_factor: float = 1.25,
                    act_bytes: Optional[int] = None,
                    loads=None, hot_ids=None) -> ModeResult:
    """Latency of one MoE layer under two-tier hot/cold placement
    (``core.strategy`` ``hybrid``): hot experts stream through the fast
    chiplet array as a double-buffered expert flow (DDR load chain +
    D2D ring broadcast feeding whole-array compute), cold experts
    execute *in place* on the near-memory tier (``hw.ndp``), and the
    layer finishes at ``max(tier_fast, tier_ndp)``.

    Discrete twin of ``core.autotune.hybrid_cost``: serial per-expert
    load/compute chains per tier instead of closed-form aggregates, so
    rank agreement between the two is a meaningful check.  The
    structural tax of global placement is modeled too: routing +
    capacity dispatch run un-sharded on one fast die before the tiers
    start (the hot/cold partition is not aligned with any token
    sharding).

    ``hot_ids`` pins the fast-tier expert set (e.g. the static top-N
    baseline, or the engine's EMA partition); ``None`` sweeps every
    prefix of the load-descending expert order and keeps the best —
    the idealized dynamic repartition.  ``loads`` as in
    :func:`simulate_mode`; ``None`` models uniform padded capacity.
    """
    if hw.ndp is None:
        raise ValueError("simulate_hybrid needs a near-memory tier "
                         "(HardwareConfig.ndp)")
    P = hw.num_chiplets
    E, d, de = spec.num_experts, spec.d_model, spec.d_expert
    wb = spec.bytes_per_param or hw.bytes_per_param
    ab = act_bytes if act_bytes is not None else hw.bytes_per_act
    eb = spec.n_mats * d * de * wb
    C = _capacity(max(1, tokens), spec, capacity_factor)
    if loads is None:
        rows_e = np.full(E, float(C))
    else:
        l = np.asarray(loads, np.float64)
        rows_e = np.minimum(float(C), tokens * spec.top_k * l)

    # un-sharded routing + capacity dispatch on one fast die — the
    # centralization tax of global hot/cold placement
    dispatch_flops = 2.0 * tokens * E * C * d * 2 + 2.0 * tokens * d * E
    t_dispatch = dispatch_flops / hw.tops

    def _tiers(hot: frozenset) -> float:
        # fast tier: serial DDR chain + ring broadcast feeding the
        # whole-array compute chain, double-buffered (one flow)
        load_done = comp_done = 0.0
        order = np.argsort(-rows_e, kind="stable")
        for e in order:
            r = rows_e[int(e)]
            if loads is not None and r < 0.5:
                continue                       # dynamic flow skips idle
            flops = 2.0 * spec.n_mats * r * d * de
            if int(e) in hot:
                load_done += eb / hw.ddr_total
                ring = load_done + (P - 1) * (eb / (P * hw.d2d_gbps)
                                              + hw.d2d_hop_latency)
                comp_done = max(comp_done, ring) \
                    + flops / (hw.tops * P)
        # near-memory tier: serial per-expert compute/local-read overlap
        ndp_done = 0.0
        cold_rows = 0.0
        for e in range(E):
            r = rows_e[e]
            if e in hot or (loads is not None and r < 0.5):
                continue
            flops = 2.0 * spec.n_mats * r * d * de
            ndp_done += max(flops / hw.ndp.tops, eb / hw.ndp.gbps)
            cold_rows += r
        if cold_rows:
            # dispatched rows shuttle to the memory tier and back
            ndp_done += 2.0 * cold_rows * d * ab / hw.d2d_gbps \
                + 2.0 * hw.d2d_hop_latency
        return max(comp_done, ndp_done)

    if hot_ids is not None:
        best = _tiers(frozenset(int(e) for e in hot_ids))
    else:
        desc = np.argsort(-rows_e, kind="stable")
        best = min(_tiers(frozenset(int(e) for e in desc[:H]))
                   for H in range(E + 1))
    lat = t_dispatch + best
    return ModeResult("hybrid", lat, best, 0.0, t_dispatch, eb * E)


def simulate_trajectory(hw: HardwareConfig, spec: ModelSpec, counts, *,
                        order=None, padded: bool = False,
                        capacity_factor: float = 1.25,
                        resident=None) -> float:
    """Step time of one MoE layer executed as a double-buffered expert
    *flow*: DDR streams expert weights in trajectory order while the
    array computes the previously-loaded expert (paper Fig. 4/5).

    ``counts`` are per-expert token-activation counts; ``order`` the
    trajectory (expert visit order — ``None`` = canonical index order);
    ``padded`` models the shape-only static plan, which knows nothing of
    the gating: every expert is loaded and computed at its full
    capacity-padded row count.  A dynamic (count-built) trajectory skips
    idle experts and computes the observed rows — and its hot/cold
    pairing keeps the DDR stream hidden behind compute instead of
    piling memory-bound experts into a compute-idle tail.

    Serial-resource model: one DDR load chain (total array bandwidth)
    feeding one compute chain (total array throughput), double-buffered
    — ``load_done(i+1)`` may run during ``compute(i)``.  Deliberately
    not the closed-form cost model, so dynamic-vs-static comparisons
    against ``core.autotune``'s load-aware predictions are meaningful.

    ``resident`` is an iterable of expert ids whose weights are pinned
    on-package by the EMA-hot weight tier (``docs/quantization.md``):
    those experts compute without touching the DDR chain at all, so a
    trajectory that leads with its resident experts hides the cold
    tail's stream behind their compute.
    """
    counts = np.asarray(counts, np.float64)
    E = spec.num_experts
    tokens = counts.sum() / max(1, spec.top_k)
    C = _capacity(max(1, int(math.ceil(tokens))), spec, capacity_factor)
    if order is None:
        order = range(E)
    resident = frozenset(int(e) for e in resident) if resident else frozenset()
    tops = hw.tops * hw.num_chiplets
    ddr = hw.ddr_total
    t_load = spec.expert_bytes_on(hw) / ddr
    load_done = 0.0
    comp_done = 0.0
    for e in order:
        rows = C if padded else min(C, counts[int(e)])
        if not padded and rows <= 0:
            continue                       # dynamic trajectory skips idle
        flops = 2.0 * spec.n_mats * rows * spec.d_model * spec.d_expert
        if int(e) in resident:
            comp_done = comp_done + flops / tops   # no DDR stream at all
            continue
        load_done = load_done + t_load     # serial DDR stream
        comp_done = max(comp_done, load_done) + flops / tops
    return comp_done


def replay_trace(hw: HardwareConfig, spec: ModelSpec, trace, *,
                 capacity_factor: float = 1.25) -> float:
    """Total modeled seconds of a serving-engine workload trace, replayed
    record by record through :func:`simulate_trajectory` — the discrete
    event-loop referee of the engine's closed-form per-record clock
    (``autotune.ServingCostModel`` / the ``modeled_s`` field, see
    docs/trace-format.md and docs/benchmarks.md).

    Each record is one MoE layer's observed expert counts for one
    iteration.  Dynamic-schedule records replay along their recorded EMA
    trajectory (falling back to the record's paired-load ``order``);
    static records replay the shape-only capacity-padded plan.  Records
    with no routed tokens are skipped (no expert flow, no step time).
    Records carrying a ``resident`` list (the engine's EMA-hot weight
    tier) skip those experts' DDR loads during replay.  Records carrying
    a ``hot`` list (the hybrid strategy's fast-tier partition) replay
    through :func:`simulate_hybrid` when the hardware has a near-memory
    tier (on homogeneous hardware the partition is placement-only and
    the record replays like any other).
    """
    total = 0.0
    for rec in trace:
        if "counts" not in rec:
            continue                    # cache_hit/preempt/restore events
        counts = np.asarray(rec["counts"], np.float64)
        if counts.sum() <= 0:
            continue
        if hw.ndp is not None and rec.get("hot") is not None:
            tokens = max(1, int(math.ceil(counts.sum()
                                          / max(1, spec.top_k))))
            total += simulate_hybrid(
                hw, spec, tokens, capacity_factor=capacity_factor,
                loads=counts / counts.sum(),
                hot_ids=rec["hot"]).latency
            continue
        resident = rec.get("resident")
        if rec.get("schedule") == "dynamic":
            order = rec.get("trajectory")
            if order is None:
                order = rec["order"]
            total += simulate_trajectory(hw, spec, counts, order=order,
                                         capacity_factor=capacity_factor,
                                         resident=resident)
        else:
            total += simulate_trajectory(hw, spec, counts, padded=True,
                                         capacity_factor=capacity_factor,
                                         resident=resident)
    return total


def schedule_step_times(hw: HardwareConfig, spec: ModelSpec, counts, *,
                        capacity_factor: float = 1.25) -> Dict[str, float]:
    """Static-vs-dynamic trajectory step times for one observed gating.

    ``static`` is the shape-only plan (canonical order, capacity-padded,
    loads every expert); ``dynamic`` the count-built paired-load
    trajectory (``core.policies.paired_load_order``); ``dynamic_unpaired``
    isolates the pairing gain (same skipping/rows, canonical order).
    """
    from repro.core.policies import paired_load_order
    return {
        "static": simulate_trajectory(hw, spec, counts, padded=True,
                                      capacity_factor=capacity_factor),
        "dynamic": simulate_trajectory(hw, spec, counts,
                                       order=paired_load_order(counts),
                                       capacity_factor=capacity_factor),
        "dynamic_unpaired": simulate_trajectory(
            hw, spec, counts, capacity_factor=capacity_factor),
    }


def rank_families(hw: HardwareConfig, spec: ModelSpec, tokens: int, *,
                  B: int, S: int,
                  capacity_factor: float = 1.25,
                  loads=None) -> Dict[str, float]:
    """Simulated latency per execution *family* of the (B, S) shape —
    the independent referee of the cross-family ``auto`` planner
    (``repro.core.strategy.family_costs``).

    ``fse_dp`` is the best ring (stream/index) schedule over its
    micro-slice candidates; when no ring layout lowers the family is
    out of the race (its degraded slice dataflow is exactly ``tp``,
    which has its own entry).  ``ep`` is the discrete all-to-all
    simulation when E % P == 0 and the tokens can seq- or batch-shard.
    ``hybrid`` (two-tier hot/cold placement) joins the race only when
    the hardware carries a near-memory tier (``hw.ndp``).  ``loads``
    conditions every family on a normalized per-expert load vector,
    mirroring ``family_costs(load=...)``.
    """
    from repro.core.autotune import _micro_candidates, feasible_modes
    from repro.core.strategy import ep_feasible
    P = hw.num_chiplets
    de_loc = max(1, spec.d_expert // P)
    out: Dict[str, float] = {}
    ring = [m for m in feasible_modes(B, S, P) if m != "slice"]
    if ring:
        out["fse_dp"] = min(
            simulate_mode(hw, spec, m, tokens, micro_slices=M,
                          capacity_factor=capacity_factor,
                          loads=loads).latency
            for m in ring for M in _micro_candidates(de_loc, 0))
    if ep_feasible(B, S, spec.num_experts, P):
        out["ep"] = simulate_ep(hw, spec, tokens,
                                capacity_factor=capacity_factor,
                                loads=loads).latency
    out["tp"] = simulate_mode(hw, spec, "slice", tokens,
                              capacity_factor=capacity_factor,
                              loads=loads).latency
    if hw.ndp is not None:
        out["hybrid"] = simulate_hybrid(hw, spec, tokens,
                                        capacity_factor=capacity_factor,
                                        loads=loads).latency
    return out


def rank_modes(hw: HardwareConfig, spec: ModelSpec, tokens: int, *,
               B: int, S: int, micro_slices: Optional[int] = None,
               capacity_factor: float = 1.25) -> Dict[str, float]:
    """Simulated latency for every *feasible* mode of the (B, S) shape.

    With ``micro_slices=None`` each ring mode is simulated at its own best
    micro-slice count (mirroring the planner, which also optimizes M per
    mode) so the comparison is schedule-vs-schedule, not knob-vs-knob.
    """
    from repro.core.autotune import _micro_candidates, feasible_modes
    P = hw.num_chiplets
    de_loc = max(1, spec.d_expert // P)
    out = {}
    for mode in feasible_modes(B, S, P):
        cands = [micro_slices] if micro_slices or mode == "slice" \
            else _micro_candidates(de_loc, 0)
        out[mode] = min(
            simulate_mode(hw, spec, mode, tokens, micro_slices=m or 1,
                          capacity_factor=capacity_factor).latency
            for m in cands)
    return out
