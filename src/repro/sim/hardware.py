"""Chiplet-array hardware model (paper Table I).

All constants default to the paper's taped-out 2×2 5nm MCM prototype:
DDR3-1600 4×25.6 GB/s, UCIe D2D 288 GB/s per chiplet, 2048-MAC compute
dies at 800 MHz (4.865 TOPS), FDI-to-FDI latency ≈ 4 ns/hop.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional


@dataclass(frozen=True)
class NDPConfig:
    """Optional near-memory compute tier (NDP/PIM dies beside the DRAM
    stacks).  All figures are *tier totals*, not per-die: the tier is a
    pool of weak MACs sitting on very wide local DRAM ports, so cold
    experts execute in place without crossing the DDR bottleneck.
    Defaults follow the HD-MoE / GPU-NDP operating point: ~1/16 of the
    2x2 array's compute, ~4x its external DDR bandwidth locally.
    """
    tops: float = 1.2e12              # tier-total near-memory ops/s
    gbps: float = 409.6e9             # tier-total local DRAM bandwidth (bytes/s)
    buffer_bytes: int = 2 * 2 ** 20   # per-tier staging SRAM


@dataclass(frozen=True)
class HardwareConfig:
    rows: int = 2
    cols: int = 2
    tops: float = 4.865e12            # per-die peak ops/s (MAC*2*freq class)
    d2d_gbps: float = 288e9           # per-chiplet D2D bandwidth (bytes/s)
    d2d_hop_latency: float = 4.02e-9  # FDI-to-FDI (s/hop)
    ddr_channels: int = 4
    ddr_gbps_per_channel: float = 25.6e9
    buffer_bytes: int = 8 * 2 ** 20   # per-die SRAM available for expert weights
    bytes_per_param: int = 2          # bf16 weights
    bytes_per_act: int = 2
    freq_hz: float = 800e6
    ndp: Optional[NDPConfig] = None   # near-memory tier (None = homogeneous)

    @property
    def num_chiplets(self) -> int:
        return self.rows * self.cols

    @property
    def ddr_total(self) -> float:
        return self.ddr_channels * self.ddr_gbps_per_channel

    def hops(self, a: int, b: int) -> int:
        """Manhattan distance on the 2D mesh."""
        ra, ca = divmod(a, self.cols)
        rb, cb = divmod(b, self.cols)
        return abs(ra - rb) + abs(ca - cb)


# paper Table I prototype
PROTOTYPE_2X2 = HardwareConfig()


def with_ndp(base: HardwareConfig = PROTOTYPE_2X2,
             ndp: Optional[NDPConfig] = None) -> HardwareConfig:
    """The heterogeneous variant of an array: same chiplets + DDR, plus a
    near-memory tier.  The NDP defaults scale with the base array's DDR
    bandwidth (local ports are ~4x the external channels)."""
    import dataclasses
    if ndp is None:
        ndp = NDPConfig(gbps=4.0 * base.ddr_total)
    return dataclasses.replace(base, ndp=ndp)


# the prototype with the default near-memory tier attached
PROTOTYPE_2X2_NDP = with_ndp()


def scaled(rows: int, cols: int, base: HardwareConfig = PROTOTYPE_2X2) -> HardwareConfig:
    """Scale the array (DDR channels grow with the array *edge*, as in
    §VI-E — ``max(rows, cols)``, so a 2x4 and a 4x2 array get the same
    DDR and odd edges still scale).  A base NDP tier's local bandwidth
    grows with the DDR it sits beside."""
    import dataclasses
    channels = base.ddr_channels * max(2, max(rows, cols)) // 2
    out = dataclasses.replace(base, rows=rows, cols=cols,
                              ddr_channels=channels)
    if base.ndp is not None:
        ratio = channels / max(1, base.ddr_channels)
        out = dataclasses.replace(out, ndp=dataclasses.replace(
            base.ndp, gbps=base.ndp.gbps * ratio))
    return out


@dataclass(frozen=True)
class ModelSpec:
    """What the simulator needs to know about one MoE layer."""
    name: str
    d_model: int
    d_expert: int
    num_experts: int
    top_k: int
    n_mats: int = 3                   # swiglu: gate+up+down
    num_layers: int = 1
    d_ff_dense: int = 0               # attention-adjacent dense FFN (e2e only)
    num_heads: int = 16
    num_shared: int = 0
    bytes_per_param: Optional[int] = None  # streamed expert-weight bytes;
    #   None = the hardware default (bf16).  1 models int8/fp8 streaming.

    def expert_bytes_on(self, hw: HardwareConfig) -> int:
        """Streamed DDR bytes of one expert's weights on ``hw`` — a
        ``None`` ``bytes_per_param`` falls back to the *hardware*
        default, so a 4-byte hardware profile streams 4-byte weights."""
        return self.n_mats * self.d_model * self.d_expert \
            * (self.bytes_per_param or hw.bytes_per_param)

    @property
    def expert_bytes(self) -> int:
        """Hardware-free view: resolves a ``None`` ``bytes_per_param``
        against the Table-I prototype's default.  Call sites that know
        their :class:`HardwareConfig` use :meth:`expert_bytes_on`."""
        return self.expert_bytes_on(PROTOTYPE_2X2)

    def expert_flops_per_token(self) -> float:
        return 2.0 * self.n_mats * self.d_model * self.d_expert


def spec_from_config(cfg, weight_bytes: Optional[int] = None, *,
                     hw: Optional[HardwareConfig] = None) -> ModelSpec:
    """Build a sim spec from a repro ModelConfig (must have MoE).

    ``weight_bytes`` overrides the streamed expert-weight storage width
    (e.g. 1 for an int8/fp8 ``ExecutionSpec.weight_dtype`` run) so the
    simulator referee and the closed-form cost model agree on DDR bytes.
    With ``weight_bytes=None``, ``hw`` pins the spec's weight width to
    that hardware's ``bytes_per_param`` (otherwise it stays ``None`` and
    resolves per call site via :meth:`ModelSpec.expert_bytes_on`).
    """
    assert cfg.moe is not None
    if weight_bytes is None and hw is not None:
        weight_bytes = hw.bytes_per_param
    return ModelSpec(
        name=cfg.name, d_model=cfg.d_model, d_expert=cfg.moe.d_expert,
        num_experts=cfg.moe.num_experts, top_k=cfg.moe.top_k,
        n_mats=3 if cfg.activation == "swiglu" else 2,
        num_layers=cfg.num_layers, d_ff_dense=cfg.d_ff,
        num_heads=max(1, cfg.num_heads), num_shared=cfg.moe.num_shared_experts,
        bytes_per_param=weight_bytes)


# paper Table I models for the simulator benchmarks
PAPER_SPECS = {
    "phi3.5-moe": ModelSpec("phi3.5-moe", 4096, 3200, 16, 2, 3, 32, 3200, 32),  # Table-I d_ffn
    "yuan2-m32": ModelSpec("yuan2-m32", 2048, 4096, 32, 2, 3, 24, 4096, 16),
    "deepseek-moe": ModelSpec("deepseek-moe", 2048, 1408, 64, 6, 3, 28, 1408, 16, 2),
    "qwen3-a3b": ModelSpec("qwen3-a3b", 2048, 768, 128, 8, 3, 48, 768, 32),
}
