"""Discrete-event chiplet simulator for MoE layer execution.

Implements the paper's virtualization rules at micro-slice granularity:

  Rule 1 — a micro-slice received in the previous step is computed
           immediately while simultaneously being forwarded along the
           trajectory (compute queue is LIFO on arrival time);
  Rule 2 — if nothing was just received, any resident micro-slice is
           computed/forwarded (the LIFO stack degenerates to this);
  Rule 3 — storage is released after the last station's compute;
  Rule 4 — DDR loads proceed whenever a channel and destination buffer
           space are available;
  Rule 5 — (optional) DDR steers each load to the trajectory chiplet
           with the most free buffer.

The same event engine also runs the EP / Hydra baselines (experts
pinned to an owner chiplet, tokens travel, whole-expert residency with
double-buffered prefetch) so all strategies share the identical
hardware model.  Expert admission follows Algorithm 1 (spatiotemporal
trajectory scheduling) driven by the idle-chiplet vector.
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.policies import paired_load_order
from .hardware import HardwareConfig, ModelSpec
from .workload import LayerWorkload


@dataclass
class LayerResult:
    latency: float
    utilization: float                  # mean compute-busy fraction
    peak_buffer_bytes: int              # package-wide peak
    peak_buffer_per_chip: np.ndarray
    ddr_bytes: float
    d2d_bytes: float
    busy_time: np.ndarray               # per-chiplet compute busy seconds
    timeline: List[tuple] = field(default_factory=list)  # (t, chip, kind, dur)
    dropped_experts: List[int] = field(default_factory=list)

    @property
    def util_curve(self):
        return self.timeline

    def util_series(self, bins: int = 32) -> np.ndarray:
        """Binned compute-utilization curve in [0, 1].

        Splits the makespan into ``bins`` equal windows and returns the
        fraction of (chiplet x window) capacity spent in ``compute:*``
        timeline events (needs ``record_timeline=True``).
        """
        P = len(self.peak_buffer_per_chip)
        span = max(self.latency, 1e-12)
        width = span / bins
        busy = np.zeros(bins, np.float64)
        for t, _chip, kind, dur in self.timeline:
            if not str(kind).startswith("compute"):
                continue
            t0, t1 = t, min(t + dur, span)
            b0 = min(bins - 1, int(t0 / width))
            b1 = min(bins - 1, int(max(t1 - 1e-18, t0) / width))
            for b in range(b0, b1 + 1):
                lo, hi = b * width, (b + 1) * width
                busy[b] += max(0.0, min(t1, hi) - max(t0, lo))
        return busy / (P * width)


class _MicroSlice:
    __slots__ = ("uid", "expert", "idx", "bytes", "route", "pos",
                 "computed_here", "xfer_done_here", "arrival")

    def __init__(self, uid, expert, idx, nbytes, route):
        self.uid = uid
        self.expert = expert
        self.idx = idx
        self.bytes = nbytes
        self.route = route            # list of chiplet ids to visit, in order
        self.pos = 0                  # index into route (current station)
        self.computed_here = False
        self.xfer_done_here = True    # no inbound transfer initially
        self.arrival = 0.0

    @property
    def station(self):
        return self.route[self.pos]

    @property
    def last(self):
        return self.pos == len(self.route) - 1


class ChipletSim:
    """One MoE layer on the chiplet array under a given strategy."""

    def __init__(self, hw: HardwareConfig, spec: ModelSpec, wl: LayerWorkload,
                 *, strategy: str = "fse_dp", micro_slices: int = 8,
                 order: str = "paired", rule5: bool = False,
                 max_inflight_experts: Optional[int] = None,
                 record_timeline: bool = False):
        assert strategy in ("fse_dp", "fse_dp_naive", "ep", "hydra")
        self.hw, self.spec, self.wl = hw, spec, wl
        self.P = hw.num_chiplets
        self.strategy = strategy
        self.micro = max(1, micro_slices)
        self.order = order
        self.rule5 = rule5
        self.record_timeline = record_timeline
        self.max_inflight = max_inflight_experts or max(2, self.P)
        self._uid = itertools.count()
        self._seq = itertools.count()

    # ---------------- shared machinery ----------------

    def _expert_order(self) -> List[int]:
        totals = self.wl.expert_totals
        active = [e for e in range(self.spec.num_experts) if totals[e] > 0]
        if self.order == "paired":
            return [e for e in paired_load_order(totals) if totals[e] > 0]
        if self.order == "sorted":
            return sorted(active, key=lambda e: -totals[e])
        return active

    def _trajectory(self, e: int) -> List[int]:
        """Chiplets holding tokens for e, ring order (logical ring, §VI-A)."""
        chips = [c for c in range(self.P) if self.wl.counts[c, e] > 0]
        return chips

    def _compute_time(self, chip: int, e: int, frac: float) -> float:
        n_tok = int(self.wl.counts[chip, e])
        return n_tok * self.spec.expert_flops_per_token() * frac / self.hw.tops

    # ---------------- event-driven run ----------------

    def run(self) -> LayerResult:
        hw, spec = self.hw, self.spec
        P = self.P
        now = 0.0
        events: List[tuple] = []

        order = self._expert_order()
        # pending expert queue (Algorithm 1's E_sorted)
        queue: List[int] = list(order)
        inflight: Dict[int, int] = {}          # expert -> outstanding micro-slices
        idle = np.ones(P, bool)                # ICV — idle-chiplet vector

        # resources
        compute_free = np.zeros(P)             # next free time per chip engine
        compute_stack: List[List[_MicroSlice]] = [[] for _ in range(P)]
        computing: List[Optional[_MicroSlice]] = [None] * P
        port_free = np.zeros(P)                # out-port next free time
        xfer_queue: List[List[_MicroSlice]] = [[] for _ in range(P)]
        buf_used = np.zeros(P)
        buf_peak = np.zeros(P)
        ddr_free = np.zeros(hw.ddr_channels)
        pending_loads: List[Tuple[int, _MicroSlice]] = []   # (entry_chip, ms)
        busy = np.zeros(P)
        ddr_bytes = 0.0
        d2d_bytes = 0.0
        timeline: List[tuple] = []
        dropped: List[int] = []

        whole_expert = self.strategy in ("ep", "hydra")
        if whole_expert:
            self.max_inflight = spec.num_experts + 1

        # --- placement for EP / Hydra ---
        owner = {}
        if whole_expert:
            totals = self.wl.expert_totals
            if self.strategy == "ep":
                for e in range(spec.num_experts):
                    owner[e] = e % P
            else:  # hydra: greedy least-loaded by token count (popularity-aware)
                load = np.zeros(P)
                for e in sorted(range(spec.num_experts), key=lambda e: -totals[e]):
                    c = int(np.argmin(load))
                    owner[e] = c
                    load[c] += totals[e] * spec.expert_flops_per_token() / hw.tops \
                        + spec.expert_bytes / hw.ddr_total

        def unit_count(traj_len: int) -> int:
            # two-level split (paper Fig. 4): expert -> per-chiplet slice ->
            # micro-slices; auto-refine so one unit fits half a buffer
            n = traj_len * self.micro
            while spec.expert_bytes / n > hw.buffer_bytes / 2 and n < 4096:
                n += traj_len
            return n

        def make_slices(e: int) -> List[_MicroSlice]:
            traj = self._trajectory(e)
            if not traj:
                return []
            if whole_expert:
                # whole expert resident at owner; tokens travel (handled as
                # extra pre/post token-transfer time charged to compute chain)
                route = [owner[e]]
                n = 1
                nbytes = spec.expert_bytes
            else:
                route = traj
                n = unit_count(len(traj))
                nbytes = spec.expert_bytes / n
            out = []
            for i in range(n):
                # entry chiplet: slices round-robin over the trajectory
                entry = route[i % len(route)]
                start = route.index(entry)
                ring = route[start:] + route[:start]
                ms = _MicroSlice(next(self._uid), e, i, nbytes, ring)
                out.append(ms)
            return out

        def token_io_time(e: int) -> float:
            """EP/Hydra: gather tokens to the owner + scatter results back."""
            n_remote = int(self.wl.expert_totals[e] - self.wl.counts[owner[e], e])
            vol = 2.0 * n_remote * spec.d_model * hw.bytes_per_act  # in + out
            return vol / hw.d2d_gbps + hw.d2d_hop_latency

        def try_admit():
            """Algorithm 1 main loop body."""
            admitted = True
            while admitted and queue and len(inflight) < self.max_inflight:
                admitted = False
                # pass 1: expert whose trajectory covers an idle chiplet
                for qi, e in enumerate(queue):
                    traj = self._trajectory(e)
                    if not traj:
                        queue.pop(qi)
                        dropped.append(e)
                        admitted = True
                        break
                    if any(idle[c] for c in traj):
                        queue.pop(qi)
                        admit(e, traj)
                        admitted = True
                        break
                if admitted:
                    continue
                # pass 2 (Rule 4 / Alg.1 line 12): preload next expert if any
                # buffer anywhere on its trajectory has room for one slice
                e = queue[0]
                traj = self._trajectory(e)
                need = spec.expert_bytes if whole_expert \
                    else spec.expert_bytes / unit_count(len(traj))
                if any(buf_used[c] + need <= hw.buffer_bytes for c in traj):
                    queue.pop(0)
                    admit(e, traj)
                    admitted = True

        def admit(e: int, traj: List[int]):
            slices = make_slices(e)
            inflight[e] = len(slices)
            for c in traj:
                idle[c] = False
            for ms in slices:
                pending_loads.append((ms.route[0], ms))

        def try_start_loads():
            nonlocal ddr_bytes
            i = 0
            while i < len(pending_loads):
                entry, ms = pending_loads[i]
                if self.rule5 and not whole_expert:
                    # Rule 5: steer to trajectory chiplet with most free buffer
                    entry = min(ms.route, key=lambda c: buf_used[c])
                    start = ms.route.index(entry)
                    ms.route = ms.route[start:] + ms.route[:start]
                    ms.pos = 0
                if whole_expert:
                    # double-buffered prefetch: at most 2 experts resident
                    if buf_used[entry] >= 2 * spec.expert_bytes:
                        i += 1
                        continue
                elif buf_used[entry] + 2 * ms.bytes > hw.buffer_bytes:
                    # Rule 4 + one receive slot of headroom (ring deadlock
                    # avoidance: transfers must always be able to land)
                    i += 1
                    continue
                pending_loads.pop(i)
                buf_used[entry] += ms.bytes
                buf_peak[entry] = max(buf_peak[entry], buf_used[entry])
                ch = int(np.argmin(ddr_free))
                dur = ms.bytes / hw.ddr_gbps_per_channel
                t0 = max(now, ddr_free[ch])
                ddr_free[ch] = t0 + dur
                ddr_bytes += ms.bytes
                if self.record_timeline:
                    timeline.append((t0, entry, f"load:e{ms.expert}:u{ms.uid}",
                                     dur))
                heapq.heappush(events, (t0 + dur, next(self._seq), "load_done", ms))

        def try_start_compute():
            for c in range(P):
                if computing[c] is not None or compute_free[c] > now:
                    continue
                if not compute_stack[c]:
                    continue
                ms = compute_stack[c].pop()      # LIFO — Rule 1 (eager)
                computing[c] = ms
                frac = ms.bytes / spec.expert_bytes   # unit's share of the expert
                dur = self._compute_time(c, ms.expert, frac)
                if whole_expert:
                    dur += token_io_time(ms.expert)
                busy[c] += dur
                compute_free[c] = now + dur
                if self.record_timeline:
                    timeline.append((now, c, f"compute:e{ms.expert}:u{ms.uid}",
                                     dur))
                heapq.heappush(events, (now + dur, next(self._seq), "compute_done", ms))
                # Rule 1: forward simultaneously with compute
                if not ms.last:
                    ms.xfer_done_here = False
                    xfer_queue[c].append(ms)

        def try_start_xfers():
            nonlocal d2d_bytes
            for c in range(P):
                if port_free[c] > now or not xfer_queue[c]:
                    continue
                ms = xfer_queue[c][0]
                dst = ms.route[ms.pos + 1]
                # Transfers always land (elastic micro-slice buffer, §VI-B):
                # gating only DDR injection keeps the ring deadlock-free while
                # the reported peak shows any capacity exceedance.
                xfer_queue[c].pop(0)
                buf_used[dst] += ms.bytes        # reserve at receiver
                buf_peak[dst] = max(buf_peak[dst], buf_used[dst])
                hops = max(1, self.hw.hops(c, dst))
                dur = ms.bytes / hw.d2d_gbps + hops * hw.d2d_hop_latency
                port_free[c] = now + dur
                d2d_bytes += ms.bytes
                if self.record_timeline:
                    timeline.append((now, c, f"xfer:e{ms.expert}:u{ms.uid}",
                                     dur))
                heapq.heappush(events, (now + dur, next(self._seq), "xfer_done", (ms, c, dst)))

        def maybe_release(ms: _MicroSlice, chip: int):
            """Rule 3 + post-forward release at intermediate stations."""
            if ms.computed_here and ms.xfer_done_here:
                buf_used[chip] -= ms.bytes
                if ms.last:
                    finish_slice(ms)
                else:
                    ms.pos += 1
                    ms.computed_here = False
                    ms.xfer_done_here = True
                    ms.arrival = now
                    compute_stack[ms.station].append(ms)

        def finish_slice(ms: _MicroSlice):
            inflight[ms.expert] -= 1
            if inflight[ms.expert] == 0:
                del inflight[ms.expert]
                # Alg.1 line 15: release chiplets not engaged elsewhere
                engaged = set()
                for st in compute_stack:
                    engaged.update(m.station for m in st)
                for e2 in inflight:
                    engaged.update(self._trajectory(e2))
                for c in range(P):
                    if c not in engaged and computing[c] is None:
                        idle[c] = True

        try_admit()
        try_start_loads()
        guard = 0
        while events or pending_loads or any(compute_stack) or any(xfer_queue) \
                or queue or inflight:
            guard += 1
            if guard > 2_000_000:
                raise RuntimeError("simulator livelock")
            if not events:
                raise RuntimeError(
                    f"sim deadlock at t={now:.3e}: loads={len(pending_loads)} "
                    f"queue={len(queue)} inflight={dict(inflight)}")
            else:
                t, _, kind, payload = heapq.heappop(events)
                now = max(now, t)
                if kind == "load_done":
                    ms = payload
                    ms.computed_here = False
                    ms.xfer_done_here = True
                    ms.arrival = now
                    compute_stack[ms.station].append(ms)
                elif kind == "compute_done":
                    ms = payload
                    chip = ms.station
                    computing[chip] = None
                    ms.computed_here = True
                    if ms.last:
                        ms.xfer_done_here = True
                    maybe_release(ms, chip)
                elif kind == "xfer_done":
                    ms, src, dst = payload
                    ms.xfer_done_here = True
                    maybe_release(ms, src)
            try_admit()
            try_start_loads()
            try_start_xfers()
            try_start_compute()

        makespan = max(now, 1e-12)
        util = float(busy.sum() / (P * makespan))
        return LayerResult(
            latency=makespan, utilization=util,
            peak_buffer_bytes=int(buf_peak.sum()),
            peak_buffer_per_chip=buf_peak.copy(),
            ddr_bytes=ddr_bytes, d2d_bytes=d2d_bytes, busy_time=busy.copy(),
            timeline=timeline, dropped_experts=dropped)


# ---------------------------------------------------------------------------
# A1: naive FSE-DP (phase-synchronized, no fine-grained flow) — §III
# ---------------------------------------------------------------------------

def simulate_naive_fsedp(hw: HardwareConfig, spec: ModelSpec,
                         wl: LayerWorkload) -> LayerResult:
    P = hw.num_chiplets
    totals = wl.expert_totals
    t = 0.0
    busy = np.zeros(P)
    ddr_bytes = 0.0
    d2d_bytes = 0.0
    peak = np.zeros(P)
    for e in range(spec.num_experts):
        if totals[e] == 0:
            continue
        traj = [c for c in range(P) if wl.counts[c, e] > 0]
        S = len(traj)
        slice_bytes = spec.expert_bytes / S
        # load S slices in parallel over DDR channels (no overlap w/ compute)
        t_load = slice_bytes / hw.ddr_gbps_per_channel * np.ceil(S / hw.ddr_channels)
        ddr_bytes += spec.expert_bytes
        # S synchronized phases: each phase max(compute, transfer)
        t_phases = 0.0
        for ph in range(S):
            comp = max(wl.counts[c, e] * spec.expert_flops_per_token() / S / hw.tops
                       for c in traj)
            xfer = slice_bytes / hw.d2d_gbps + hw.d2d_hop_latency if S > 1 else 0.0
            t_phases += comp + (xfer if ph < S - 1 else 0.0)
            d2d_bytes += slice_bytes * (S if ph < S - 1 else 0)
        for c in traj:
            busy[c] += wl.counts[c, e] * spec.expert_flops_per_token() / hw.tops
        # double residency: current slice + incoming slice (paper §IV point 1)
        for c in traj:
            peak[c] = max(peak[c], 2 * slice_bytes)
        t += t_load + t_phases
    makespan = max(t, 1e-12)
    return LayerResult(latency=makespan, utilization=float(busy.sum() / (P * makespan)),
                       peak_buffer_bytes=int(peak.sum()), peak_buffer_per_chip=peak,
                       ddr_bytes=ddr_bytes, d2d_bytes=d2d_bytes, busy_time=busy)


# ---------------------------------------------------------------------------
# strategy front-door
# ---------------------------------------------------------------------------

def simulate_layer(hw: HardwareConfig, spec: ModelSpec, wl: LayerWorkload,
                   strategy: str, **kw) -> LayerResult:
    """strategy: ep | hydra | fse_dp_naive (A1) | fse_dp (A2) |
    fse_dp_paired (A3) | fse_dp_rule5 (A4)."""
    if strategy == "fse_dp_naive":
        return simulate_naive_fsedp(hw, spec, wl)
    if strategy == "fse_dp":
        return ChipletSim(hw, spec, wl, strategy="fse_dp", order="natural", **kw).run()
    if strategy == "fse_dp_paired":
        return ChipletSim(hw, spec, wl, strategy="fse_dp", order="paired", **kw).run()
    if strategy == "fse_dp_rule5":
        return ChipletSim(hw, spec, wl, strategy="fse_dp", order="paired",
                          rule5=True, **kw).run()
    if strategy in ("ep", "hydra"):
        return ChipletSim(hw, spec, wl, strategy=strategy, **kw).run()
    raise ValueError(strategy)
