"""End-to-end multi-iteration simulation (paper §VI-C, Fig. 14/15).

Runs N forward iterations of attention + all MoE layers under a
strategy, with optional token buffering (Algorithm 2 via
``repro.core.policies.TokenBufferPolicy``).  A deferred request pauses
at its MoE layer: its remaining-layer tokens are carried into the next
iteration's workloads (re-batched with new tokens — the paper's
re-evaluation of expert-activation patterns), bounded by QoS slack.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.core.policies import TokenBufferPolicy
from .hardware import HardwareConfig, ModelSpec
from .workload import Request, make_requests, make_layer_workload, LayerWorkload
from .engine import simulate_layer


@dataclass
class E2EResult:
    total_time: float
    tokens_processed: int
    iterations: int
    throughput: float                  # tokens/s
    mean_utilization: float
    deferral_events: int
    peak_buffer_bytes: int
    per_iteration_latency: List[float] = field(default_factory=list)


def _attention_time(hw: HardwareConfig, spec: ModelSpec, tokens: int,
                    ctx: int = 1024) -> float:
    """Head-parallel attention across chiplets + dense QKVO projections.

    flops = qkvo projections + score/value matmuls against a ``ctx``-long
    KV cache; KV cache streamed from DDR.
    """
    d = spec.d_model
    proj = 4.0 * tokens * d * d * 2
    attn = 2.0 * tokens * ctx * d * 2
    t_compute = (proj + attn) / (hw.num_chiplets * hw.tops)
    kv_bytes = 2.0 * ctx * d * hw.bytes_per_act * max(1, tokens // 16)
    return t_compute + kv_bytes / hw.ddr_total


def run_e2e(hw: HardwareConfig, spec: ModelSpec, *, strategy: str,
            tokens_per_iter: int, iterations: int = 20, seed: int = 0,
            buffering_slack: float = 0.0, theta_min: int = 4,
            layer_sample: Optional[int] = None, ctx: int = 1024) -> E2EResult:
    """layer_sample: simulate this many MoE layers per iteration and scale
    (keeps the benchmark wall-time sane for 48-layer models)."""
    rng = np.random.default_rng(seed)
    policy = TokenBufferPolicy.from_slack(buffering_slack, theta_min=theta_min) \
        if buffering_slack > 0 else None

    n_layers = spec.num_layers
    sample = layer_sample or n_layers
    sample = min(sample, n_layers)
    scale = n_layers / sample

    total_time = 0.0
    tokens_done = 0
    deferrals = 0
    utils: List[float] = []
    peaks: List[int] = []
    per_iter: List[float] = []

    # requests persist across iterations (decode-style: the same mixed
    # prefill/decode request set contributes tokens every forward pass);
    # deferred requests carry their resume layer into the next iteration
    pool = make_requests(tokens_per_iter, hw.num_chiplets, seed * 997)
    if policy is not None:
        for r in pool:
            policy.state(r.rid).timer = 1   # arrival credit (one deferral)
    carry: List[tuple] = []    # (Request, resume_layer_idx)

    for it in range(iterations):
        carried_ids = {r.rid for r, _ in carry}
        active: List[tuple] = [(r, 0) for r in pool if r.rid not in carried_ids] + carry
        carry = []
        iter_time = _attention_time(hw, spec, sum(r.num_tokens for r, _ in active),
                                    ctx=ctx)
        layer_ids = sorted(rng.choice(n_layers, size=sample, replace=False)) \
            if sample < n_layers else list(range(n_layers))

        for li, layer in enumerate(layer_ids):
            live = [(r, s) for (r, s) in active if s <= layer]
            if not live:
                continue
            wl = make_layer_workload(spec, [r for r, _ in live],
                                     hw.num_chiplets, layer, seed * 31 + it)
            if policy is not None:
                totals = wl.expert_totals
                kept: List[Request] = []
                for r, s in live:
                    acts = wl.per_request.get(r.rid, [])
                    if acts and policy.should_defer(r.rid, acts, totals):
                        deferrals += 1
                        carry.append((r, layer))
                        active = [(rr, ss) for (rr, ss) in active if rr.rid != r.rid]
                    else:
                        kept.append(r)
                if len(kept) != len(live):
                    wl = make_layer_workload(spec, kept, hw.num_chiplets,
                                             layer, seed * 31 + it)
                if not kept:
                    continue
            res = simulate_layer(hw, spec, wl, strategy)
            iter_time += res.latency * scale / 1.0 * (1.0 if sample == n_layers else 1.0)
            utils.append(res.utilization)
            peaks.append(res.peak_buffer_bytes)
        if sample < n_layers:
            # scale the sampled-MoE portion up to the full depth
            moe_part = iter_time - _attention_time(
                hw, spec, sum(r.num_tokens for r, _ in active) or 1, ctx=ctx)
            iter_time += moe_part * (scale - 1.0)

        total_time += iter_time
        per_iter.append(iter_time)
        done_tokens = sum(r.num_tokens for r, s in active)
        tokens_done += done_tokens
        if policy is not None:
            for r, _ in active:
                policy.on_forward_pass(r.rid)

    return E2EResult(
        total_time=total_time, tokens_processed=tokens_done,
        iterations=iterations,
        throughput=tokens_done / max(total_time, 1e-12),
        mean_utilization=float(np.mean(utils)) if utils else 0.0,
        deferral_events=deferrals,
        peak_buffer_bytes=max(peaks) if peaks else 0,
        per_iteration_latency=per_iter)
