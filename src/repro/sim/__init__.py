from .hardware import (HardwareConfig, ModelSpec, NDPConfig, PROTOTYPE_2X2,
                       PROTOTYPE_2X2_NDP, PAPER_SPECS, scaled,
                       spec_from_config, with_ndp)
from .workload import LayerWorkload, Request, iteration_workloads, make_requests, make_layer_workload
from .engine import ChipletSim, LayerResult, simulate_layer, simulate_naive_fsedp
from .e2e import E2EResult, run_e2e
from .modes import ModeResult, rank_modes, simulate_hybrid, simulate_mode
