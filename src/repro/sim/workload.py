"""Long-tail MoE activation workloads (paper §II-B, Fig. 2).

Per-layer expert-activation counts are generated from a request-mixed
Zipf/Dirichlet model calibrated to the paper's observation: with 16–256
tokens per iteration a handful of experts absorb most tokens while a
non-negligible fraction receive 0–2 tokens, and the skew sharpens as
the token count shrinks.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List

import numpy as np

from .hardware import ModelSpec


@dataclass
class LayerWorkload:
    """Expert token counts for one MoE layer in one iteration.

    counts[c][e] — tokens on chiplet ``c`` activating expert ``e``.
    per_request[rid] — list of expert ids activated by request ``rid``.
    """
    counts: np.ndarray                    # (chiplets, E) int
    per_request: Dict[str, List[int]] = field(default_factory=dict)

    @property
    def expert_totals(self) -> np.ndarray:
        return self.counts.sum(axis=0)

    @property
    def total_tokens(self) -> int:
        # each token activates top_k experts; counts are per-activation
        return int(self.counts.sum())


@dataclass
class Request:
    rid: str
    num_tokens: int
    home_chiplet: int
    affinity_seed: int                     # per-request expert affinity


def sample_expert_probs(E: int, rng: np.random.Generator,
                        zipf_s: float = 1.1) -> np.ndarray:
    """Zipf-ranked probabilities with random rank permutation."""
    ranks = np.arange(1, E + 1, dtype=np.float64)
    p = ranks ** (-zipf_s)
    p /= p.sum()
    return p[rng.permutation(E)]


def route_tokens(E: int, top_k: int, num_tokens: int, probs: np.ndarray,
                 rng: np.random.Generator) -> np.ndarray:
    """Counts (E,) of token-activations via top-k draws w/o replacement."""
    counts = np.zeros(E, np.int64)
    for _ in range(num_tokens):
        # Gumbel top-k == sampling w/o replacement by probs
        g = np.log(probs + 1e-12) + rng.gumbel(size=E)
        picks = np.argpartition(-g, top_k)[:top_k]
        counts[picks] += 1
    return counts


def make_layer_workload(spec: ModelSpec, requests: List[Request],
                        num_chiplets: int, layer_idx: int, seed: int,
                        mix: float = 0.5) -> LayerWorkload:
    """Per-request routing = mix·layer-global Zipf + (1-mix)·request affinity."""
    rng = np.random.default_rng(seed * 1000003 + layer_idx)
    global_p = sample_expert_probs(spec.num_experts, rng)
    counts = np.zeros((num_chiplets, spec.num_experts), np.int64)
    per_request: Dict[str, List[int]] = {}
    for req in requests:
        rrng = np.random.default_rng(req.affinity_seed * 7919 + layer_idx)
        local_p = sample_expert_probs(spec.num_experts, rrng)
        p = mix * global_p + (1 - mix) * local_p
        p /= p.sum()
        c = route_tokens(spec.num_experts, spec.top_k, req.num_tokens, p, rng)
        counts[req.home_chiplet] += c
        per_request[req.rid] = [int(e) for e in np.nonzero(c)[0]]
    return LayerWorkload(counts=counts, per_request=per_request)


def make_requests(tokens_per_iter: int, num_chiplets: int, seed: int,
                  avg_request_tokens: int | None = None) -> List[Request]:
    """Split an iteration's token budget into mixed prefill/decode requests."""
    rng = np.random.default_rng(seed)
    if avg_request_tokens is None:
        avg_request_tokens = max(1, tokens_per_iter // 8)
    reqs: List[Request] = []
    remaining = tokens_per_iter
    i = 0
    while remaining > 0:
        n = int(min(remaining, max(1, rng.poisson(avg_request_tokens))))
        reqs.append(Request(rid=f"r{seed}_{i}", num_tokens=n,
                            home_chiplet=i % num_chiplets,
                            affinity_seed=int(rng.integers(1 << 30))))
        remaining -= n
        i += 1
    return reqs


def workload_from_counts(counts, num_chiplets: int,
                         per_request: Dict[str, List[int]] | None = None
                         ) -> LayerWorkload:
    """Engine-observed per-expert totals -> a chiplet-resolved workload.

    The serving engine traces total activations per expert (its tokens
    have no chiplet placement); the simulator wants (chiplets, E).
    Tokens are striped across chiplets round-robin with a per-expert
    rotating offset so the remainder does not always land on chiplet 0.
    Exactness invariant (tested): ``result.expert_totals == counts``.
    """
    counts = np.asarray(counts, np.int64)
    E = counts.shape[0]
    out = np.zeros((num_chiplets, E), np.int64)
    for e in range(E):
        q, r = divmod(int(counts[e]), num_chiplets)
        out[:, e] = q
        for j in range(r):
            out[(e + j) % num_chiplets, e] += 1
    return LayerWorkload(counts=out, per_request=dict(per_request or {}))


def workloads_from_trace(trace, num_chiplets: int):
    """Replay a serving-engine workload trace into simulator workloads.

    ``trace`` is ``Engine.trace``: records with ``iter`` / ``layer`` /
    ``counts`` (see README trace-format spec; prefill-chunk and decode
    records both qualify, event records without ``counts`` —
    cache_hit/preempt/restore — are skipped).
    Returns ``[(iter, layer, LayerWorkload)]``
    in trace order — feed each through ``sim.engine.simulate_layer`` or
    ``sim.modes`` to cross-validate the engine's schedule decisions.
    """
    return [(int(rec["iter"]), int(rec["layer"]),
             workload_from_counts(rec["counts"], num_chiplets))
            for rec in trace if "counts" in rec]


def trace_expert_totals(trace) -> Dict[int, np.ndarray]:
    """Aggregate a serving-engine trace to per-layer expert loads.

    The engine<->simulator conformance check: these totals must equal
    the summed ``expert_totals`` of the replayed workloads exactly.
    """
    totals: Dict[int, np.ndarray] = {}
    for rec in trace:
        if "counts" not in rec:
            continue                    # cache_hit/preempt/restore events
        c = np.asarray(rec["counts"], np.int64)
        layer = int(rec["layer"])
        if layer in totals:
            totals[layer] = totals[layer] + c
        else:
            totals[layer] = c.copy()
    return totals


def iteration_workloads(spec: ModelSpec, tokens_per_iter: int,
                        num_chiplets: int, seed: int) -> List[LayerWorkload]:
    """One workload per MoE layer for a single forward iteration."""
    reqs = make_requests(tokens_per_iter, num_chiplets, seed)
    return [make_layer_workload(spec, reqs, num_chiplets, l, seed)
            for l in range(spec.num_layers)]
