"""Mesh context — lets model code find the active mesh without jax globals.

The launcher / trainer / tests wrap tracing in ``with_mesh(mesh)``; the
distributed MoE implementations read it via ``get_mesh()`` and fall back
to single-device execution when no mesh (or a trivial one) is active.
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Optional

import jax

_MESH: contextvars.ContextVar = contextvars.ContextVar("repro_mesh", default=None)


@contextlib.contextmanager
def with_mesh(mesh: Optional[jax.sharding.Mesh]):
    tok = _MESH.set(mesh)
    try:
        yield mesh
    finally:
        _MESH.reset(tok)


def get_mesh() -> Optional[jax.sharding.Mesh]:
    return _MESH.get()


def model_axis_size(axis: str = "model") -> int:
    mesh = get_mesh()
    if mesh is None or axis not in mesh.axis_names:
        return 1
    return mesh.shape[axis]


def batch_axes(mesh, axis: str = "model"):
    """All mesh axes except the model axis (used for batch sharding specs)."""
    return tuple(a for a in mesh.axis_names if a != axis)


# ---------------------------------------------------------------------------
# optimization flags (§Perf hillclimb knobs; default = paper-faithful baseline)
# ---------------------------------------------------------------------------

_OPTS: contextvars.ContextVar = contextvars.ContextVar("repro_opts", default=frozenset())


@contextlib.contextmanager
def with_opts(*names: str):
    """Enable named optimizations: 'sorted' (sort-based MoE dispatch),
    'sp_attn' (explicit SP all-gather at attention entry),
    'scatter_cache' (scatter KV update instead of one-hot)."""
    tok = _OPTS.set(frozenset(_OPTS.get()) | set(names))
    try:
        yield
    finally:
        _OPTS.reset(tok)


def opt_enabled(name: str) -> bool:
    return name in _OPTS.get()
