"""Sharding rules: map every parameter / cache / batch leaf to a
PartitionSpec on the (pod, data, model) production mesh.

Conventions (Megatron-TP + SP on ``model``; DP/FSDP on ``pod``+``data``):

  embed (V,d)              -> (None, model)
  lm_head (d,V)            -> (fsdp?, model)
  attn wq/wk/wv (d, H*hd)  -> (fsdp?, model)        heads sharded
  attn wo (H*hd, d)        -> (model, fsdp?)
  ffn w_up/w_gate (d, f)   -> (fsdp?, model)
  ffn w_down (f, d)        -> (model, fsdp?)
  MoE w_gate/w_up (E,d,de) -> (None, fsdp?, model)  d_expert sharded — the
  MoE w_down (E,de,d)      -> (None, model, fsdp?)  FSE-DP layout (one copy
                                                    of every expert per group)
  ssm in_proj (d, Z)       -> (fsdp?, model)
  ssm out_proj (di, d)     -> (model, fsdp?)
  router / norms / scalars -> replicated

``fsdp?`` = the ``data`` axis for architectures above the FSDP
threshold (ZeRO-3-style param+state sharding; needed to fit e.g.
nemotron-4-340b in 16 GB/chip), None otherwise.  Every proposed axis is
divisibility-guarded: non-dividing dims fall back to replication.

Decode caches: KV sequence dim sharded over ``model`` (sequence-
parallel decode — softmax over the sharded axis lowers to psum), batch
over (pod, data); SSM state heads over ``model``.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

FSDP_THRESHOLD = 20e9   # params; above this, in-dims shard over 'data'


def _axis_size(mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return mesh.shape[axis]


def _fit(mesh, axis, dim: int):
    """axis if it divides dim (and exists in the mesh), else None."""
    if axis is None:
        return None
    if isinstance(axis, tuple):
        axes = tuple(a for a in axis if a in mesh.axis_names)
        if not axes:
            return None
        if dim % _axis_size(mesh, axes) == 0:
            return axes if len(axes) > 1 else axes[0]
        # try shrinking the product
        for sub in (axes[1:], axes[:1]):
            if sub and dim % _axis_size(mesh, sub) == 0:
                return sub if len(sub) > 1 else sub[0]
        return None
    if axis not in mesh.axis_names:
        return None
    return axis if dim % mesh.shape[axis] == 0 else None


def _pad(spec_tail, ndim: int) -> P:
    tail = tuple(spec_tail)
    assert len(tail) <= ndim, (tail, ndim)
    return P(*((None,) * (ndim - len(tail)) + tail))


_LAST = lambda path: path.split("/")[-1]

def path_key(path) -> str:
    parts = []
    for p in path:
        for attr in ("key", "idx", "name"):
            if hasattr(p, attr):
                parts.append(str(getattr(p, attr)))
                break
        else:
            parts.append(str(p))
    return "/".join(parts)



def param_spec(path: str, shape, mesh, *, fsdp: bool) -> P:
    """PartitionSpec for a parameter leaf, identified by its path name."""
    name = _LAST(path)
    nd = len(shape)
    f = ("pod", "data") if fsdp else None   # ZeRO dims span pods when present
    model = "model"
    in_moe = "moe" in path.split("/")

    if name in ("scale", "bias", "A_log", "D", "dt_bias", "conv_b"):
        return P()
    if name == "w_router":
        return P()
    if name == "embed":
        return _pad((None, _fit(mesh, model, shape[-1])), nd)
    if name == "lm_head":
        return _pad((_fit(mesh, f, shape[-2]), _fit(mesh, model, shape[-1])), nd)
    if name in ("wq", "wk", "wv"):
        return _pad((_fit(mesh, f, shape[-2]), _fit(mesh, model, shape[-1])), nd)
    if name == "wo":
        return _pad((_fit(mesh, model, shape[-2]), _fit(mesh, f, shape[-1])), nd)
    if name in ("w_up", "w_gate"):
        if in_moe and nd >= 3:   # (E, d, de): FSE-DP d_expert sharding
            return _pad((None, _fit(mesh, f, shape[-2]), _fit(mesh, model, shape[-1])), nd)
        return _pad((_fit(mesh, f, shape[-2]), _fit(mesh, model, shape[-1])), nd)
    if name == "w_down":
        if in_moe and nd >= 3:   # (E, de, d)
            return _pad((None, _fit(mesh, model, shape[-2]), _fit(mesh, f, shape[-1])), nd)
        return _pad((_fit(mesh, model, shape[-2]), _fit(mesh, f, shape[-1])), nd)
    if name == "in_proj":
        return _pad((_fit(mesh, f, shape[-2]), _fit(mesh, model, shape[-1])), nd)
    if name == "out_proj":
        return _pad((_fit(mesh, model, shape[-2]), _fit(mesh, f, shape[-1])), nd)
    if name == "conv_w":
        return _pad((None, _fit(mesh, model, shape[-1])), nd)
    return P()   # anything unrecognized: replicate


def _tree_specs(tree, mesh, fn):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        out.append(fn(path_key(path), leaf.shape))
    return jax.tree_util.tree_unflatten(treedef, out)


def param_shardings(params_struct, mesh, *, fsdp: bool):
    return _tree_specs(params_struct, mesh,
                       lambda k, s: NamedSharding(mesh, param_spec(k, s, mesh, fsdp=fsdp)))


def opt_shardings(opt_struct, params_struct, mesh, *, fsdp: bool):
    """m/v follow their parameter's spec; step is replicated."""
    pspec = param_shardings(params_struct, mesh, fsdp=fsdp)
    rep = NamedSharding(mesh, P())
    return type(opt_struct)(step=rep, m=pspec, v=pspec)


def cache_spec(path: str, shape, mesh, *, batch_axes) -> P:
    """Decode-cache leaf specs (see module docstring)."""
    name = _LAST(path)
    nd = len(shape)
    if nd == 0:
        return P()
    if name in ("k", "v"):           # (nper, B, S, kv, hd)
        b = _fit(mesh, batch_axes, shape[-4])
        s = _fit(mesh, "model", shape[-3])
        return _pad((b, s, None, None), nd)
    if name == "ssd":                # (nper, B, nh, hd, n)
        b = _fit(mesh, batch_axes, shape[-4])
        h = _fit(mesh, "model", shape[-3])
        return _pad((b, h, None, None), nd)
    if name == "conv":               # (nper, B, K, d_xBC)
        b = _fit(mesh, batch_axes, shape[-3])
        dd = _fit(mesh, "model", shape[-1])
        return _pad((b, None, dd), nd)
    if name in ("cross_k", "cross_v"):   # (L, B, F, kv, hd)
        b = _fit(mesh, batch_axes, shape[-4])
        return _pad((b, None, None, None), nd)
    # fallback: shard the largest dim that fits the batch axes
    return _pad((_fit(mesh, batch_axes, shape[1]) if nd > 1 else None,), min(nd, 2))


def cache_shardings(cache_struct, mesh, batch_axes):
    return _tree_specs(cache_struct, mesh,
                       lambda k, s: NamedSharding(mesh, cache_spec(k, s, mesh,
                                                                   batch_axes=batch_axes)))


def batch_spec(name: str, shape, mesh, batch_axes) -> P:
    nd = len(shape)
    b = _fit(mesh, batch_axes, shape[0]) if nd else None
    return P(*((b,) + (None,) * (nd - 1)))


def batch_shardings(batch_struct, mesh, batch_axes):
    return {k: NamedSharding(mesh, batch_spec(k, v.shape, mesh, batch_axes))
            for k, v in batch_struct.items()}


def replicated(mesh):
    return NamedSharding(mesh, P())


# ---------------------------------------------------------------------------
# in-model constraints (used inside traced model code; no-ops without a mesh)
# ---------------------------------------------------------------------------

def constrain_batch_only(x):
    """Pin an activation to batch-only sharding (model-axis replicated).

    Decode q/k_new/v_new use this so the KV cache keeps its sequence-
    parallel sharding instead of being resharded to head-sharding every
    step (a whole-cache all-gather otherwise).
    """
    from repro.parallel import meshctx
    mesh = meshctx.get_mesh()
    if mesh is None:
        return x
    baxes = tuple(a for a in mesh.axis_names if a != "model")
    b = _fit(mesh, baxes, x.shape[0]) if x.ndim else None
    spec = P(*((b,) + (None,) * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def constrain_kv_seq(x):
    """Pin a (B,S,H,hd) KV tensor to sequence-over-model sharding (the
    S-stationary decode contract: scores/outputs reduce over the sharded
    S instead of resharding the cache to head-sharding)."""
    from repro.parallel import meshctx
    mesh = meshctx.get_mesh()
    if mesh is None or x.ndim != 4 or "model" not in mesh.axis_names:
        return x
    if x.shape[1] % mesh.shape["model"]:
        return x
    baxes = tuple(a for a in mesh.axis_names if a != "model")
    b = _fit(mesh, baxes, x.shape[0])
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(b, "model", None, None)))


def constrain_seq_sharded(x):
    """Residual-stream activations (B,S,d) live sequence-sharded over
    ``model`` between layers (Megatron-SP): the scan carry then costs
    1/16th of the HBM and the attention/FFN entry gathers become the
    standard SP all-gather / reduce-scatter pair."""
    from repro.parallel import meshctx
    mesh = meshctx.get_mesh()
    if mesh is None or x.ndim != 3 or "model" not in mesh.axis_names:
        return x
    if x.shape[1] % mesh.shape["model"]:
        return x
    baxes = tuple(a for a in mesh.axis_names if a != "model")
    b = _fit(mesh, baxes, x.shape[0])
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(b, "model", None)))


def unshard_slot_params(slot):
    """ZeRO-3 per-layer gather: constrain a scan-sliced layer's params to
    their model-only (fsdp=False) sharding *inside* the loop body, so the
    FSDP all-gather happens once per layer instead of being hoisted as a
    whole-stack gather before the scan (which OOMs 340B)."""
    from repro.parallel import meshctx
    mesh = meshctx.get_mesh()
    if mesh is None:
        return slot
    flat, treedef = jax.tree_util.tree_flatten_with_path(slot)
    out = []
    for path, leaf in flat:
        spec = param_spec(path_key(path), leaf.shape, mesh, fsdp=False)
        out.append(jax.lax.with_sharding_constraint(
            leaf, NamedSharding(mesh, spec)))
    return jax.tree_util.tree_unflatten(treedef, out)
