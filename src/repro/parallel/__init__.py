from . import meshctx, sharding
from .meshctx import with_mesh, get_mesh
