"""Expert-trajectory scheduling — the *schedule* stage of the MoE pipeline.

Every execution family now runs the same four-stage pipeline
(``repro.core.strategy``):

  route    — compute a :class:`~repro.core.gating.Routing` once (or accept
             a precomputed one, e.g. from the serving engine's gate pass);
  schedule — build a :class:`Schedule` here: an expert *trajectory* (the
             order experts move through the compute/DDR pipeline), the
             complementary hot/cold stream pairing of the paper's
             paired-load policy (§IV-A), and the plan-level knobs (mode,
             micro-slices) from the load-aware cost model;
  dispatch — gather tokens into per-expert rows, reindexed into
             trajectory order;
  combine  — weighted scatter of expert outputs back to tokens (always
             in canonical expert order — see below).

A ``static`` schedule is shape-only: identity trajectory, uniform-load
cost model — bit-identical to the pre-pipeline execution paths.  A
``dynamic`` schedule is built from the *observed* per-expert token
counts (``gating.expert_token_counts``), either host-side (the engine's
EMA-tracked counts via :class:`LoadTracker`) or in-graph from the
current call's own routing (:func:`traced_order`).

The SPMD realization of a trajectory is a permutation of the expert
axis of the dispatched ``(E, C, d)`` buffer and the matching weight
stacks.  That axis is a pure batch axis of the grouped expert GEMM (the
Pallas kernel grids over it in order, so the permutation genuinely
reorders per-expert compute/weight-load timing), and the outputs are
un-permuted *before* the combine — so a dynamic schedule changes
execution order only, never values.  This is the paper's virtualization
argument (§III) made checkable: ``tests`` assert dynamic == static bit
for bit while the chiplet simulator (``sim.modes.simulate_trajectory``)
shows the paired trajectory beating the static one in step time on
skewed gating.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional, Sequence, Tuple

import numpy as np

from .autotune import Plan
from .policies import expert_pairs, paired_load_order

SCHEDULE_POLICIES = ("static", "dynamic")


@dataclass(frozen=True)
class Schedule:
    """One expert-trajectory decision for one MoE layer call.

    ``order`` is the trajectory (a permutation of expert ids, hot/cold
    interleaved for ``dynamic``): a host-side tuple, **or** a traced
    ``(E,)`` int array when the schedule is constructed inside a jitted
    computation (the serving engine's fused mega-steps feed the EMA
    trajectory in as a traced argument so the compiled step never
    retraces as the trajectory drifts).  ``None`` means *derive it
    in-graph* from the call's own routing counts when the policy is
    dynamic, or the identity trajectory when static.  ``pairs`` are the
    complementary (hot, cold) stream pairs of the paired-load policy;
    ``load`` the normalized per-expert load vector the schedule was
    planned from (``None`` = uniform); ``plan`` the load-aware
    :class:`~repro.core.autotune.Plan` when one was computed.
    """

    policy: str = "static"
    order: Optional[Tuple[int, ...]] = None
    pairs: Tuple[Tuple[int, Optional[int]], ...] = ()
    load: Optional[Tuple[float, ...]] = None
    plan: Optional[Plan] = None
    predicted_s: float = 0.0

    def __post_init__(self):
        if self.policy not in SCHEDULE_POLICIES:
            raise ValueError(f"unknown schedule policy {self.policy!r} "
                             f"(want {SCHEDULE_POLICIES})")
        # host sequences coerce to an int tuple; jax arrays / tracers
        # (anything carrying a dtype) pass through untouched so a
        # Schedule can be built at trace time from a traced order
        if self.order is not None and not hasattr(self.order, "dtype"):
            object.__setattr__(self, "order",
                               tuple(int(e) for e in self.order))

    @property
    def dynamic(self) -> bool:
        return self.policy == "dynamic"


# the sentinel moe_block passes down when ExecutionSpec.schedule ==
# "dynamic" and no host-built Schedule was provided: every strategy
# derives the trajectory in-graph from its own routing counts
DYNAMIC = Schedule(policy="dynamic")


def static_order(num_experts: int) -> Tuple[int, ...]:
    """The shape-only trajectory: canonical expert-index order."""
    return tuple(range(num_experts))


def normalized_load(counts: Sequence[float]) -> Optional[Tuple[float, ...]]:
    """Counts -> per-expert load shares (sum 1); None for an all-zero
    vector (no information — callers fall back to uniform)."""
    c = np.asarray(counts, np.float64)
    tot = float(c.sum())
    if tot <= 0:
        return None
    return tuple(float(v) for v in c / tot)


def build_schedule(counts: Optional[Sequence[int]] = None, *,
                   policy: str = "dynamic",
                   plan: Optional[Plan] = None,
                   predicted_s: float = 0.0) -> Schedule:
    """Host-side schedule from observed (or EMA-tracked) expert counts.

    ``static`` ignores the counts entirely (identity trajectory, uniform
    load).  ``dynamic`` orders the trajectory by the paired-load policy
    and records the pairing + the normalized load vector, so the plan
    the caller computed from that load travels with the schedule.
    """
    if policy == "static" or counts is None:
        return Schedule(policy="static", plan=plan, predicted_s=predicted_s)
    return Schedule(policy="dynamic",
                    order=tuple(paired_load_order(counts)),
                    pairs=tuple(expert_pairs(counts)),
                    load=normalized_load(counts),
                    plan=plan, predicted_s=predicted_s)


# ---------------------------------------------------------------------------
# EMA load feedback (decode re-plans as gating drifts)
# ---------------------------------------------------------------------------


@dataclass
class LoadTracker:
    """Exponential moving average of per-expert activation counts.

    The serving engine keeps one per MoE layer and feeds each
    iteration's observed counts back in, so the next iteration's
    dynamic schedule (and the load-aware cost model) tracks gating
    drift instead of re-planning from a single noisy step.
    """

    num_experts: int
    decay: float = 0.8
    steps: int = 0
    ema: np.ndarray = field(default=None)  # type: ignore[assignment]

    def __post_init__(self):
        if self.ema is None:
            self.ema = np.zeros((self.num_experts,), np.float64)

    def update(self, counts: Sequence[int]) -> np.ndarray:
        c = np.asarray(counts, np.float64)
        if self.steps == 0:
            self.ema = c.copy()
        else:
            self.ema = self.decay * self.ema + (1.0 - self.decay) * c
        self.steps += 1
        return self.ema

    def load_vector(self) -> Optional[Tuple[float, ...]]:
        """Normalized EMA load shares; None before any observation."""
        if self.steps == 0:
            return None
        return normalized_load(self.ema)

    def schedule(self, *, plan: Optional[Plan] = None) -> Schedule:
        """A dynamic Schedule from the tracked EMA counts."""
        if self.steps == 0:
            return Schedule(policy="dynamic")      # derive in-graph
        return build_schedule(self.ema, policy="dynamic", plan=plan)


# ---------------------------------------------------------------------------
# in-graph trajectory (traced counts -> traced order)
# ---------------------------------------------------------------------------


def traced_order(counts):
    """jnp analogue of ``policies.paired_load_order`` for traced counts.

    Hot/cold interleave of the descending-stable sort: order[2i] is the
    i-th hottest expert, order[2i+1] the i-th coldest.  Idle experts
    (zero counts) sort as the coldest and interleave with the hot end
    rather than trailing as in the host version — they carry zero rows,
    so their position is timing-immaterial; the fixed-shape interleave
    keeps the computation trace-safe.
    """
    import jax.numpy as jnp
    E = counts.shape[0]
    desc = jnp.argsort(-jnp.asarray(counts), stable=True).astype(jnp.int32)
    half = (E + 1) // 2
    order = jnp.zeros((E,), jnp.int32)
    order = order.at[0::2].set(desc[:half])
    order = order.at[1::2].set(desc[half:][::-1])
    return order


def resolve_order(schedule: Optional[Schedule],
                  counts_fn: Callable[[], "object"]):
    """The trajectory permutation one execution body should apply.

    ``None`` (static — the untouched fast path), a constant array (a
    host-built dynamic schedule, e.g. the engine's EMA trajectory), or
    a traced array derived from this call's own routing counts
    (``counts_fn`` is only invoked in that case).
    """
    if schedule is None or not schedule.dynamic:
        return None
    import jax.numpy as jnp
    if schedule.order is not None:
        return jnp.asarray(schedule.order, jnp.int32)
    return traced_order(counts_fn())


def apply_order(order, *arrays):
    """Reindex the leading (expert) axis of each array into trajectory
    order.  ``None`` entries (gateless w_gate) pass through."""
    import jax.numpy as jnp
    return tuple(None if a is None else jnp.take(a, order, axis=0)
                 for a in arrays)


def restore_order(order, ye):
    """Undo :func:`apply_order` on the expert outputs *before* the
    combine, so a dynamic trajectory never changes combine numerics."""
    import jax.numpy as jnp
    return jnp.take(ye, jnp.argsort(order), axis=0)
