"""Top-K expert gating (router) with load-balancing auxiliary loss.

Routing follows the Mixtral/DeepSeek convention: softmax over all expert
logits, select top-k, renormalize the selected probabilities.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init


class Routing(NamedTuple):
    indices: jnp.ndarray      # (T, k) int32 selected experts
    weights: jnp.ndarray      # (T, k) renormalized gate weights
    probs: jnp.ndarray        # (T, E) full softmax (for aux loss / stats)
    combine: jnp.ndarray      # (T, E) scatter of weights into expert slots


def router_init(key, d_model, num_experts, dtype):
    return {"w_router": dense_init(key, d_model, num_experts, dtype, scale=0.02)}


def route(params, x, *, top_k, jitter=0.0, key=None) -> Routing:
    """x: (T, d) -> Routing over E experts."""
    logits = (x @ params["w_router"]).astype(jnp.float32)     # (T, E)
    if jitter and key is not None:
        logits = logits + jitter * jax.random.normal(key, logits.shape)
    probs = jax.nn.softmax(logits, axis=-1)
    weights, indices = jax.lax.top_k(probs, top_k)            # (T,k)
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
    E = probs.shape[-1]
    onehot = jax.nn.one_hot(indices, E, dtype=jnp.float32)    # (T,k,E)
    combine = jnp.einsum("tk,tke->te", weights, onehot)       # (T,E)
    return Routing(indices, weights.astype(x.dtype), probs, combine.astype(x.dtype))


def aux_load_balance_loss(routing: Routing, num_experts: int) -> jnp.ndarray:
    """Switch-transformer style: E * sum_e f_e * p_e."""
    T = routing.probs.shape[0]
    assign = (routing.combine > 0).astype(jnp.float32)        # (T,E)
    f = assign.sum(0) / jnp.maximum(assign.sum(), 1.0)        # fraction routed
    p = routing.probs.mean(0)                                 # mean prob
    return num_experts * jnp.sum(f * p)


def expert_token_counts(routing: Routing, mask=None) -> jnp.ndarray:
    """(E,) number of tokens activating each expert (the paper's n_e).

    ``mask`` restricts the count to a boolean (T,) subset of the routed
    rows — e.g. the serving engine counting only its active slots."""
    assign = routing.combine > 0                              # (T,E)
    if mask is not None:
        assign = assign & jnp.asarray(mask)[:, None]
    return assign.sum(0)
