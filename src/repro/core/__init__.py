from . import autotune, gating, policies, strategy, trajectory
from .autotune import HardwareProfile, Plan, plan_moe, use_autotune
from .strategy import (ExecutionSpec, MoEStrategy, StrategyContext,
                       available, execute, get_strategy, plan_family,
                       register)
from .trajectory import LoadTracker, Schedule, build_schedule
# deprecated one-line shims (warn on call) — the registry is the API
from .fse_dp import fse_dp_moe_3d
from .baselines import ep_moe_3d, tp_moe_3d
from .policies import paired_load_order, expert_pairs, TokenBufferPolicy
