from . import autotune, gating, policies
from .autotune import HardwareProfile, Plan, plan_moe, use_autotune
from .fse_dp import fse_dp_moe_3d, pick_mode
from .baselines import ep_moe_3d, tp_moe_3d
from .policies import paired_load_order, expert_pairs, TokenBufferPolicy
