"""Cost-model-driven trajectory autotuner for FSE-DP.

The paper's scheduling contribution is *dynamic* expert-trajectory
selection; the SPMD adaptation in ``core.fse_dp`` realizes trajectories
as three execution modes (stream / index / slice) plus two granularity
knobs (ring ``micro_slices`` and the Pallas kernel tile shapes).  This
module replaces the static three-line ``pick_mode`` heuristic with an
analytical per-mode cost model:

* compute FLOPs (expert GEMMs + dispatch/combine one-hots + router),
* interconnect bytes (ring ``ppermute`` traffic, index/slice psum
  all-reduce, token all-gather for replicated layouts),
* HBM/DDR traffic of the kernel's block revisits,
* VMEM footprint of the streamed weight blocks,

all parameterized by a :class:`HardwareProfile` derived from the chiplet
simulator's :class:`~repro.sim.hardware.HardwareConfig` (or TPU-class
constants from ``launch.analysis``).  At trace time the planner scores
{stream, index, slice} x candidate ``micro_slices`` x kernel tile shapes
and returns the winning :class:`Plan`; ``fse_dp_moe_3d`` dispatches on
it.  ``pick_mode`` survives only as the zero-knowledge fallback
(``level="off"`` or unknown hardware).

An optional *measured* path times candidate kernel lowerings once
(through ``kernels.ops``) and memoizes the winner to an on-disk JSON
cache under ``artifacts/autotune/`` so subsequent traces are free.

The model is validated against the cycle-level chiplet simulator
(``sim.modes.simulate_mode``): ``tests/test_autotune.py`` asserts rank
agreement on a (B, S, E, d_expert, P) sweep and
``benchmarks/autotune_bench.py`` records predicted-vs-measured times.
"""
from __future__ import annotations

import contextlib
import contextvars
import functools
import json
import math
import os
import warnings
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Tuple

MODES = ("stream", "index", "slice")

# (B, S, E, d_expert, P) validation sweep shared by tests/test_autotune.py
# and benchmarks/autotune_bench.py: low-batch decode (slice regime),
# prefill (stream regime), and batch-heavy decode with S < P (index
# regime), at d_model=512 on the Table-I chiplet arrays.
VALIDATION_SWEEP: Tuple[Tuple[int, int, int, int, int], ...] = (
    (1, 1, 16, 512, 4), (8, 1, 16, 512, 4), (4, 16, 8, 256, 4),
    (1, 128, 16, 512, 4), (1, 2, 64, 256, 8),
    (4, 512, 16, 512, 4), (2, 1024, 8, 1024, 2), (8, 1024, 32, 512, 8),
    (512, 1, 32, 256, 8), (2048, 1, 16, 512, 4), (1024, 2, 64, 256, 8),
    (16, 1, 8, 1024, 2), (3, 1, 16, 512, 4), (2, 2048, 16, 768, 4),
)

# autotune level: "off" (pick_mode + config micro_slices + kernel-default
# tiles — the pre-autotuner behavior), "analytic" (cost-model plan, the
# default), "measured" (analytic mode choice + wall-clock-timed tiles).
_LEVEL = contextvars.ContextVar(
    "repro_autotune", default=os.environ.get("REPRO_AUTOTUNE", "analytic"))


@contextlib.contextmanager
def use_autotune(level: str):
    """Scope the autotune level: 'off' | 'analytic' | 'measured'."""
    if level not in ("off", "analytic", "measured"):
        raise ValueError(f"unknown autotune level {level!r}")
    tok = _LEVEL.set(level)
    try:
        yield
    finally:
        _LEVEL.reset(tok)


def autotune_level() -> str:
    return _LEVEL.get()


# ---------------------------------------------------------------------------
# hardware profile
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class HardwareProfile:
    """What the cost model needs to know about one device + its links."""

    name: str
    peak_flops: float          # per-device peak FLOP/s
    mem_bw: float              # HBM/DDR bytes/s per device
    link_bw: float             # D2D/ICI bytes/s per device (ring neighbor)
    link_latency: float        # seconds per ring hop (collective issue cost)
    vmem_bytes: int            # fast-memory budget for one kernel working set
    # optional near-memory compute tier (HardwareConfig.ndp) — tier
    # totals; None/0.0 = homogeneous hardware, hybrid pricing inert
    ndp_flops: Optional[float] = None
    ndp_bw: float = 0.0

    @classmethod
    def from_chiplet(cls, hw=None) -> "HardwareProfile":
        """Derive from the chiplet simulator's HardwareConfig (Table I)."""
        if hw is None:
            from repro.sim.hardware import PROTOTYPE_2X2 as hw
        ndp = getattr(hw, "ndp", None)
        return cls(name=f"chiplet-{hw.rows}x{hw.cols}",
                   peak_flops=hw.tops,
                   mem_bw=hw.ddr_total / hw.num_chiplets,
                   link_bw=hw.d2d_gbps,
                   link_latency=hw.d2d_hop_latency,
                   vmem_bytes=hw.buffer_bytes,
                   ndp_flops=None if ndp is None else ndp.tops,
                   ndp_bw=0.0 if ndp is None else ndp.gbps)

    @classmethod
    def from_chiplet_array(cls, hw=None) -> "HardwareProfile":
        """Aggregate whole-array profile (Table I, all chiplets summed):
        total MAC throughput feeding on total DDR bandwidth — the
        resource view of the serial expert *flow* the chiplet referee
        (``sim.modes.simulate_trajectory``) prices.  This is the profile
        the serving engine's modeled clock uses: machine-independent by
        construction (pure Table-I constants, never detected)."""
        if hw is None:
            from repro.sim.hardware import PROTOTYPE_2X2 as hw
        ndp = getattr(hw, "ndp", None)
        return cls(name=f"chiplet-array-{hw.rows}x{hw.cols}",
                   peak_flops=hw.tops * hw.num_chiplets,
                   mem_bw=hw.ddr_total,
                   link_bw=hw.d2d_gbps,
                   link_latency=hw.d2d_hop_latency,
                   vmem_bytes=hw.buffer_bytes,
                   ndp_flops=None if ndp is None else ndp.tops,
                   ndp_bw=0.0 if ndp is None else ndp.gbps)

    @classmethod
    def from_tpu(cls) -> "HardwareProfile":
        """v5e-class constants shared with ``launch.analysis``."""
        from repro.launch import analysis
        return cls(name="tpu-v5e", peak_flops=analysis.PEAK_FLOPS,
                   mem_bw=analysis.HBM_BW, link_bw=analysis.ICI_BW,
                   link_latency=1e-6, vmem_bytes=analysis.VMEM_BYTES)

    @classmethod
    def detect(cls) -> "HardwareProfile":
        try:
            import jax
            if jax.default_backend() == "tpu":
                return cls.from_tpu()
        except Exception:  # pragma: no cover
            pass
        return cls.from_chiplet()


# ---------------------------------------------------------------------------
# plan
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Plan:
    """One fully-resolved MoE execution decision.

    ``family`` names the execution strategy that owns the plan (a
    ``repro.core.strategy`` registry key); for the FSE-DP family,
    ``mode`` further selects the SPMD dataflow (stream | index | slice).
    Non-FSE-DP families (ep / tp / capacity / dense) carry their family
    name in ``mode`` as well, so a Plan alone identifies the dataflow.
    """

    mode: str                          # stream | index | slice | <family>
    micro_slices: int
    family: str = "fse_dp"
    token_tile: int = 128
    dmodel_tile: Optional[int] = None
    dexpert_tile: Optional[int] = None
    predicted_s: float = 0.0
    vmem_bytes: int = 0
    per_mode_s: Tuple[Tuple[str, float], ...] = ()
    source: str = "analytic"           # analytic | measured | fallback | forced
    hot_experts: Optional[int] = None  # hybrid family: fast-tier expert count

    def kernel_opts(self) -> Dict[str, int]:
        """kwargs for ``kernels.ops.streamed_moe`` (only non-defaults)."""
        out: Dict[str, int] = {}
        from repro.kernels.streamed_moe import DEFAULT_TOKEN_TILE
        if self.token_tile and self.token_tile != DEFAULT_TOKEN_TILE:
            out["token_tile"] = self.token_tile
        if self.dmodel_tile is not None:
            out["dmodel_tile"] = self.dmodel_tile
        if self.dexpert_tile is not None:
            out["dexpert_tile"] = self.dexpert_tile
        return out

    @property
    def breakdown(self) -> Dict[str, float]:
        return dict(self.per_mode_s)


def _cap(tokens: int, top_k: int, E: int, cf: float) -> int:
    from repro.configs.base import moe_capacity_rows
    return moe_capacity_rows(tokens, top_k, E, cf)


def load_rows(E: int, C: int, assignments: float,
              load: Optional[Tuple[float, ...]] = None
              ) -> Tuple[float, int]:
    """(effective expert rows, active expert count) under a load vector.

    ``load`` is a normalized per-expert activation-share vector (e.g.
    from ``trajectory.normalized_load`` of observed gating counts).
    ``None`` keeps the shape-only padded-buffer model: every expert
    computes its full capacity ``C`` — exactly the pre-load-vector cost
    model, bit for bit.  With a load vector the model prices the
    trajectory-scheduled execution instead: expert ``e`` computes
    ``min(C, assignments·load_e)`` rows and idle experts (< 0.5
    expected rows) skip their weight load entirely.
    """
    if load is None:
        return float(E * C), E
    rows = 0.0
    active = 0
    for share in load:
        r = min(float(C), assignments * float(share))
        rows += r
        active += r >= 0.5
    return rows, max(1, active)


def streaming_layer_cost(E: int, C: int, d: int, de: int, n_mats: int,
                         assignments: float, profile: HardwareProfile, *,
                         dtype_bytes: int = 2,
                         weight_bytes: Optional[int] = None,
                         resident: int = 0,
                         load: Optional[Tuple[float, ...]] = None
                         ) -> Dict[str, float]:
    """Closed-form seconds for one MoE layer run as the paper's expert
    *flow*: DDR streams expert weights in trajectory order while the
    array computes the previously-loaded expert (double-buffered).

    The structure mirrors :func:`load_rows`'s two regimes — ``load=None``
    prices the shape-only static plan (every expert loaded and computed
    at its padded capacity ``C``), a load vector prices the dynamic
    trajectory (observed rows, idle experts skip their weight stream).
    ``total_s`` is ``fill + max(compute chain, remaining DDR chain)``:
    the first expert's weight load is exposed, after which the stream
    overlaps compute — the ideal-overlap bound the paired trajectory
    approaches.  Exact against the event referee at both extremes
    (compute-bound: ``fill + compute``; DDR-bound: ``active`` serial
    loads); in between it lower-bounds the event interleave.
    Deliberately closed-form: the discrete event referee is
    ``sim.modes.simulate_trajectory``, and their agreement is asserted,
    not assumed (tests/test_modeled_clock).

    Dispatch/combine one-hot FLOPs are excluded to match the referee's
    scope (it prices the expert flow only).

    ``weight_bytes`` is the *streamed* bytes per expert-weight param
    (quantized storage, ``kernels.quant``; ``None`` = ``dtype_bytes`` —
    the pre-quantization model, bit for bit; per-channel scale streams
    are ~4/d of the weight bytes and excluded).  ``resident`` experts
    (EMA-hot tiering) have their weights pinned on-package: they pay no
    DDR stream, and — because the engine pins the *hottest* experts,
    which the paired trajectory visits first — their compute hides the
    first cold expert's fill whenever any expert is resident.
    """
    rows, active = load_rows(E, C, assignments, load)
    wb = dtype_bytes if weight_bytes is None else weight_bytes
    expert_bytes = float(n_mats * d * de * wb)
    cold = max(0, active - max(0, int(resident)))
    t_comp = 2.0 * n_mats * rows * d * de / profile.peak_flops
    t_ddr = cold * expert_bytes / profile.mem_bw
    t_fill = expert_bytes / profile.mem_bw if cold == active and cold else 0.0
    return {"total_s": t_fill + max(t_comp, t_ddr - t_fill),
            "t_comp_s": t_comp, "t_ddr_s": t_ddr, "t_fill_s": t_fill,
            "rows": rows, "active": float(active), "cold": float(cold)}


@dataclass(frozen=True)
class ServingCostModel:
    """Per-MoE-layer modeled seconds for the serving engine's clock.

    One frozen bundle of model-shape constants + a
    :class:`HardwareProfile`, so the engine can turn each workload-trace
    record (observed per-expert counts + schedule policy) into
    deterministic predicted seconds: a *static* schedule prices the
    shape-only padded plan (it knows nothing of the gating), a *dynamic*
    schedule prices the observed load along the trajectory.  The default
    profile is :meth:`HardwareProfile.from_chiplet_array` — pure Table-I
    constants, so modeled TTFT/TPOT are machine-independent and the
    serving benchmark can gate them (``benchmarks/check_regression.py``).

    ``dtype_bytes`` defaults to the prototype's bf16 weights regardless
    of the host dtype: the clock models the paper's chiplet array, not
    the machine the engine happens to run on (matching the referee's
    ``ModelSpec.expert_bytes``).  ``weight_bytes`` overrides the
    *streamed* expert-weight byte width (quantized storage,
    ``kernels.quant``) without touching the activation terms.
    """

    profile: HardwareProfile
    num_experts: int
    d_model: int
    d_expert: int
    n_mats: int
    top_k: int
    capacity_factor: float
    dtype_bytes: int = 2
    weight_bytes: Optional[int] = None

    @classmethod
    def from_config(cls, cfg,
                    profile: Optional[HardwareProfile] = None,
                    weight_bytes: Optional[int] = None
                    ) -> "ServingCostModel":
        """Build from a repro ModelConfig (must have MoE)."""
        assert cfg.moe is not None, "cost model needs an MoE config"
        return cls(profile=profile or HardwareProfile.from_chiplet_array(),
                   num_experts=cfg.moe.num_experts, d_model=cfg.d_model,
                   d_expert=cfg.moe.d_expert,
                   n_mats=3 if cfg.activation == "swiglu" else 2,
                   top_k=cfg.moe.top_k,
                   capacity_factor=cfg.moe.capacity_factor,
                   weight_bytes=weight_bytes)

    @property
    def expert_bytes(self) -> int:
        """Streamed DDR bytes for one expert's weights."""
        wb = self.dtype_bytes if self.weight_bytes is None else self.weight_bytes
        return int(self.n_mats * self.d_model * self.d_expert * wb)

    def layer_s(self, counts, *, dynamic: bool = False,
                resident: int = 0, hot=None) -> float:
        """Modeled seconds for one layer's observed expert counts.

        ``resident`` is the number of would-be-loaded experts whose
        weights are pinned on-package (EMA-hot tiering): they skip
        their DDR stream term.  ``hot`` is the hybrid strategy's
        fast-tier expert-id set: on a two-tier profile
        (``profile.ndp_flops``) the layer prices as
        ``max(fast flow over hot, near-memory in-place over cold)``;
        on homogeneous hardware the partition is placement-only and
        ``hot`` is ignored (every expert still streams)."""
        total = float(sum(float(c) for c in counts))
        tokens = max(1, math.ceil(total / max(1, self.top_k)))
        C = _cap(tokens, self.top_k, self.num_experts, self.capacity_factor)
        if hot is not None and self.profile.ndp_flops and self.profile.ndp_bw:
            return self._hybrid_tiers_s(counts, C, frozenset(
                int(e) for e in hot), dynamic and total > 0)
        load = None
        if dynamic and total > 0:
            load = tuple(float(c) / total for c in counts)
        return streaming_layer_cost(
            self.num_experts, C, self.d_model, self.d_expert, self.n_mats,
            total, self.profile, dtype_bytes=self.dtype_bytes,
            weight_bytes=self.weight_bytes, resident=resident,
            load=load)["total_s"]

    def _hybrid_tiers_s(self, counts, C: int, hotset: frozenset,
                        dynamic: bool) -> float:
        """Two-tier pricing against the aggregate array profile: the hot
        tier is the usual streaming flow (fill + overlapped compute/DDR
        chains), the cold tier executes in place near memory plus a
        token shuttle over D2D; the layer is their ``max``."""
        p = self.profile
        eb = float(self.expert_bytes)
        fl = 2.0 * self.n_mats * self.d_model * self.d_expert
        hot_rows = cold_rows = 0.0
        hot_active = cold_active = 0
        for e in range(self.num_experts):
            r = min(float(C), float(counts[e])) if dynamic else float(C)
            if dynamic and r < 0.5:
                continue
            if e in hotset:
                hot_rows += r
                hot_active += 1
            else:
                cold_rows += r
                cold_active += 1
        t_hot = 0.0
        if hot_active:
            t_fill = eb / p.mem_bw
            t_hot = t_fill + max(hot_rows * fl / p.peak_flops,
                                 hot_active * eb / p.mem_bw - t_fill)
        t_cold = 0.0
        if cold_active:
            t_cold = max(cold_rows * fl / p.ndp_flops,
                         cold_active * eb / p.ndp_bw) \
                + 2.0 * cold_rows * self.d_model * self.dtype_bytes \
                / p.link_bw
        return max(t_hot, t_cold)


def feasible_modes(B: int, S: int, P: int) -> Tuple[str, ...]:
    """Which SPMD layouts lower for this global token shape."""
    out = []
    if S % P == 0 and S >= P:
        out.append("stream")
    if (B * S) % P == 0:
        out.append("index")
    out.append("slice")                # always lowers (weights stationary)
    return tuple(out)


# ---------------------------------------------------------------------------
# per-mode analytical cost
# ---------------------------------------------------------------------------


def mode_cost(mode: str, B: int, S: int, d: int, E: int, de: int,
              top_k: int, cf: float, n_mats: int, P: int,
              profile: HardwareProfile, micro_slices: int,
              dtype_bytes: int = 2,
              load: Optional[Tuple[float, ...]] = None,
              weight_bytes: Optional[int] = None) -> Dict[str, float]:
    """Predicted per-device seconds for one MoE layer under ``mode``.

    Mirrors the SPMD bodies in ``core.fse_dp`` term by term:

    stream — tokens seq-sharded (T/P local), weight micro-slices ring
             over P·M ``ppermute`` steps overlapped with the grouped GEMM;
    index  — tokens replicated, each rank takes a T/P slice, same ring,
             plus an input all-gather and an fp32 output psum;
    slice  — weights stationary, every rank routes/computes ALL tokens on
             its d_expert/P slice, fp32 output psum (no ring).

    ``load`` (a normalized per-expert load vector, see :func:`load_rows`)
    switches the expert terms from the shape-only padded-capacity model
    to the observed-gating trajectory model: rows scale with the actual
    per-expert assignments and only *active* experts pay weight
    ring/DDR traffic.  ``None`` is bit-identical to the pre-load model.

    ``weight_bytes`` is the streamed expert-weight byte width (quantized
    storage, ``kernels.quant``): it scales every weight ring/DDR term
    while activations (dispatch buffers, all-gathers, psums) keep
    ``dtype_bytes``.  ``None`` = ``dtype_bytes`` — the pre-quantization
    model, bit for bit.
    """
    T = B * S
    ab = dtype_bytes
    wb = dtype_bytes if weight_bytes is None else weight_bytes
    de_loc = de / P
    M = max(1, micro_slices)

    if mode in ("stream", "index"):
        T_loc = T / P
        C = _cap(int(math.ceil(T_loc)), top_k, E, cf)
        rows, active = load_rows(E, C, T_loc * top_k, load)
        # ring covers all P slices => full d_expert on local routed rows
        expert_flops = 2.0 * n_mats * rows * d * de
        ring_bytes = n_mats * active * d * de_loc * wb * P  # P·M sends of de_loc/M
        t_ring = ring_bytes / profile.link_bw + P * M * profile.link_latency
        t_fill = ring_bytes / (P * M) / profile.link_bw    # pipeline fill (1 slice)
        # ring quantization: a micro-slice must be fully resident before it
        # streams, so the last slice's P compute steps trail the weight
        # stream — a 1/M compute drain the slice mode (which pipelines the
        # local shard at kernel-grid granularity) does not pay
        t_drain = (expert_flops / profile.peak_flops) / M
    else:
        T_loc = T                                          # replicated routing
        C = _cap(T, top_k, E, cf)
        rows, active = load_rows(E, C, T_loc * top_k, load)
        expert_flops = 2.0 * n_mats * rows * d * de_loc    # local slice only
        ring_bytes = 0.0
        t_ring = 0.0
        t_fill = 0.0
        t_drain = 0.0

    # dispatch/combine one-hot einsums + router (per local routed tokens)
    dispatch_flops = 2.0 * T_loc * E * C * d * 2 + 2.0 * T_loc * d * E
    t_comp = (expert_flops + dispatch_flops) / profile.peak_flops

    # memory: the local weight shard streams HBM/DDR->compute once —
    # only active experts' slices under a load vector
    hbm = n_mats * active * d * de_loc * wb
    t_hbm = hbm / profile.mem_bw

    # collective extras for replicated-token layouts (ring collectives)
    t_gather = t_psum = 0.0
    if mode in ("index", "slice"):
        gather_bytes = (P - 1) / P * T * d * ab            # replicate tokens
        psum_bytes = 2.0 * (P - 1) / P * T * d * 4         # fp32 all-reduce
        t_gather = gather_bytes / profile.link_bw + profile.link_latency * (P - 1)
        t_psum = psum_bytes / profile.link_bw + 2 * profile.link_latency * (P - 1)

    overlapped = max(t_comp, t_ring, t_hbm + t_drain)
    total = overlapped + t_fill + t_gather + t_psum
    return {"total_s": total, "compute_s": t_comp, "ring_s": t_ring,
            "hbm_s": t_hbm, "gather_s": t_gather, "psum_s": t_psum,
            "fill_s": t_fill, "ring_bytes": ring_bytes,
            "flops": expert_flops + dispatch_flops, "capacity": C}


def ep_cost(B: int, S: int, d: int, E: int, de: int, top_k: int, cf: float,
            n_mats: int, P: int, profile: HardwareProfile,
            dtype_bytes: int = 2,
            load: Optional[Tuple[float, ...]] = None,
            weight_bytes: Optional[int] = None) -> Dict[str, float]:
    """Predicted per-device seconds for one MoE layer under the EP
    (expert-parallel) baseline family — the cross-family referee for the
    ``auto`` strategy (``repro.core.strategy``).

    Mirrors ``core.baselines.moe_ep`` term by term: tokens stay sharded
    (T/P local), each device owns E/P *full* experts, dispatched rows
    travel to the owning device via ``all_to_all`` and travel back after
    expert compute.  No weight movement at all (EP's structural
    advantage over the streaming family), but two all-to-alls whose
    bytes scale with the routed token rows (its structural cost).
    ``weight_bytes`` scales the local weight-shard DDR term only
    (``None`` = ``dtype_bytes``).
    """
    T = B * S
    ab = dtype_bytes
    wb = dtype_bytes if weight_bytes is None else weight_bytes
    T_loc = T / P
    C = _cap(int(math.ceil(T_loc)), top_k, E, cf)
    E_loc = E / P
    rows, active = load_rows(E, C, T_loc * top_k, load)
    # every device computes its E/P experts over the rows gathered from
    # all P ranks — same total expert flops as the ring modes (under a
    # load vector, `rows` already averages the per-rank routed rows, so
    # a device's E/P share of the P-rank total is `rows` itself)
    expert_flops = 2.0 * n_mats * (rows / E) * E_loc * P * d * de
    dispatch_flops = 2.0 * T_loc * E * C * d * 2 + 2.0 * T_loc * d * E
    t_comp = (expert_flops + dispatch_flops) / profile.peak_flops
    # local weight shard (E/P full experts — same bytes as a d_expert/P
    # slice of all experts) streams DDR/HBM once; idle experts skip
    hbm = n_mats * (active / E) * E_loc * d * de * wb
    t_hbm = hbm / profile.mem_bw
    # two all-to-alls of the routed dispatch rows; (P-1)/P cross D2D
    a2a_bytes = 2.0 * (P - 1) / P * rows * d * ab
    t_a2a = a2a_bytes / profile.link_bw + 2 * (P - 1) * profile.link_latency
    total = max(t_comp, t_hbm) + t_a2a
    return {"total_s": total, "compute_s": t_comp, "hbm_s": t_hbm,
            "a2a_s": t_a2a, "a2a_bytes": a2a_bytes,
            "flops": expert_flops + dispatch_flops, "capacity": C}


def hybrid_cost(B: int, S: int, d: int, E: int, de: int, top_k: int,
                cf: float, n_mats: int, P: int, profile: HardwareProfile,
                dtype_bytes: int = 2,
                load: Optional[Tuple[float, ...]] = None,
                weight_bytes: Optional[int] = None,
                hot_n: Optional[int] = None) -> Dict[str, float]:
    """Predicted seconds for one MoE layer under two-tier hot/cold
    placement (the ``hybrid`` family): *hot* experts stream through the
    fast chiplet array as the usual double-buffered expert flow, *cold*
    experts execute in place on the near-memory tier
    (``profile.ndp_flops`` / ``ndp_bw``), and the layer finishes at
    ``max(tier_fast, tier_ndp)`` — the HD-MoE / GPU-NDP operating
    point.  Closed-form twin of ``sim.modes.simulate_hybrid`` (the
    discrete referee); rank agreement between the two is asserted, not
    assumed (tests/test_hybrid.py).

    Global hot/cold placement is not aligned with any token sharding,
    so routing + capacity dispatch run un-sharded on ONE fast die
    before the tiers start — the centralization tax that keeps FSE-DP
    competitive at prefill.  The hot set is a prefix of the
    load-descending expert order: ``hot_n`` pins its size (static
    top-N baseline, or the engine's EMA partition width); ``None``
    sweeps every prefix and keeps the best — the idealized dynamic
    repartition.  ``load`` / ``weight_bytes`` as in :func:`mode_cost`.
    """
    if not profile.ndp_flops or not profile.ndp_bw:
        raise ValueError("hybrid_cost needs a near-memory tier "
                         "(HardwareProfile.ndp_flops / ndp_bw)")
    T = B * S
    ab = dtype_bytes
    wb = dtype_bytes if weight_bytes is None else weight_bytes
    eb = float(n_mats * d * de * wb)
    C = _cap(T, top_k, E, cf)
    if load is None:
        rows_desc = [float(C)] * E
    else:
        rows_desc = sorted((min(float(C), T * top_k * float(s))
                            for s in load), reverse=True)
    pref_rows = [0.0]
    pref_active = [0]
    for r in rows_desc:                 # prefix sums, load-descending
        act = load is None or r >= 0.5
        pref_rows.append(pref_rows[-1] + (r if act else 0.0))
        pref_active.append(pref_active[-1] + int(act))
    tot_rows, tot_active = pref_rows[-1], pref_active[-1]

    dispatch_flops = 2.0 * T * E * C * d * 2 + 2.0 * T * d * E
    t_dispatch = dispatch_flops / profile.peak_flops   # one die, un-sharded
    ddr_bw = P * profile.mem_bw                        # array-total DDR

    def _tiers(H: int) -> Tuple[float, float, float]:
        hot_rows, hot_active = pref_rows[H], pref_active[H]
        cold_rows = tot_rows - hot_rows
        cold_active = tot_active - pref_active[H]
        t_hot = 0.0
        if hot_active:
            # expert flow: exposed first load + ring broadcast, then
            # compute / DDR / ring chains overlap (double-buffered)
            t_fill = eb / ddr_bw \
                + (P - 1) * (eb / (P * profile.link_bw)
                             + profile.link_latency)
            t_comp = 2.0 * n_mats * hot_rows * d * de \
                / (P * profile.peak_flops)
            t_ddr = hot_active * eb / ddr_bw
            t_ring = hot_active * eb * (P - 1) / (P * profile.link_bw) \
                + hot_active * (P - 1) * profile.link_latency
            t_hot = t_fill + max(t_comp, t_ddr - t_fill, t_ring - t_fill)
        t_cold = 0.0
        if cold_active:
            # in-place near-memory execution + token shuttle over D2D
            t_cold = max(2.0 * n_mats * cold_rows * d * de
                         / profile.ndp_flops,
                         cold_active * eb / profile.ndp_bw)
            t_cold += 2.0 * cold_rows * d * ab / profile.link_bw \
                + 2.0 * profile.link_latency
        return max(t_hot, t_cold), t_hot, t_cold

    if hot_n is not None:
        best_H = max(0, min(E, int(hot_n)))
        best, t_hot, t_cold = _tiers(best_H)
    else:
        best = t_hot = t_cold = None
        best_H = 0
        for H in range(E + 1):
            t, th, tc = _tiers(H)
            if best is None or t < best:
                best, t_hot, t_cold, best_H = t, th, tc, H
    return {"total_s": t_dispatch + best, "dispatch_s": t_dispatch,
            "hot_s": t_hot, "cold_s": t_cold, "hot_n": float(best_H),
            "capacity": C, "rows": tot_rows, "active": float(tot_active)}


def _micro_candidates(de_loc: int, configured: int) -> List[int]:
    """Divisors of the local slice width worth trying (+ the config value)."""
    cands = {m for m in (1, 2, 4, 8, 16) if m <= de_loc and de_loc % m == 0}
    if 0 < configured <= de_loc and de_loc % configured == 0:
        cands.add(configured)
    return sorted(cands) or [1]


# ---------------------------------------------------------------------------
# kernel tile scoring (VMEM footprint + HBM revisit traffic)
# ---------------------------------------------------------------------------


# the one tile-rounding rule, shared with the kernel so planner and
# lowering can never disagree on a requested tile (satellite of the
# quantized-streaming work: previously duplicated here)
from repro.kernels.streamed_moe import fit_tile as _fit_tile  # noqa: E402


def tile_vmem_bytes(Tc: int, Ti: int, Tj: int, Tk: int, gated: bool,
                    dtype_bytes: int = 2,
                    weight_bytes: Optional[int] = None) -> int:
    """VMEM working set of one ``streamed_moe_kernel`` grid step.

    Streamed blocks (x + weights) are double-buffered by Pallas; the
    fp32 output block and the pre-activation scratch are not.
    ``weight_bytes`` is the streamed weight-block byte width (quantized
    storage; ``None`` = ``dtype_bytes``) — 1-byte formats also stream
    their per-output-channel fp32 scale rows.
    """
    n_up = 2 if gated else 1
    wb = dtype_bytes if weight_bytes is None else weight_bytes
    streamed = Tc * Ti * dtype_bytes + n_up * Ti * Tk * wb + Tk * Tj * wb
    if wb == 1:                         # int8/fp8 scale rows ride along
        streamed += (n_up * Tk + Tj) * 4
    resident = Tc * Tj * 4 + (1 + (1 if gated else 0)) * Tc * Tk * 4
    return 2 * streamed + resident


def kernel_tile_cost(E: int, C: int, d: int, m: int, Tc: int, Tj: int,
                     Tk: int, gated: bool, profile: HardwareProfile,
                     dtype_bytes: int = 2,
                     weight_bytes: Optional[int] = None) -> Dict[str, float]:
    """Roofline score of one tile choice for the grid (E, C/Tc, d/Tj, m/Tk, d/Ti).

    Models the kernel's real revisit pattern: up/gate GEMMs recompute once
    per output-d tile (d/Tj), weight blocks re-stream once per token tile
    (at ``weight_bytes`` per param when the streamed format is quantized).
    """
    n_up = 2 if gated else 1
    wb = dtype_bytes if weight_bytes is None else weight_bytes
    Ti = Tj
    Cp = math.ceil(C / Tc) * Tc
    flops = 2.0 * E * Cp * d * m * n_up * (d / Tj) + 2.0 * E * Cp * m * d
    hbm = (E * Cp * d * dtype_bytes * (d / Tj) * (m / Tk)          # x refetch
           + n_up * E * (Cp / Tc) * (d / Tj) * m * d * wb           # w_up/gate
           + E * (Cp / Tc) * (d / Ti) * m * d * wb                  # w_down
           + E * Cp * d * 4 * (m / Tk))                             # out revisits
    t = flops / profile.peak_flops + hbm / profile.mem_bw
    return {"t": t, "flops": flops, "hbm": hbm,
            "vmem": tile_vmem_bytes(Tc, Ti, Tj, Tk, gated, dtype_bytes,
                                    weight_bytes)}


def default_tiles(C: int, d: int, m: int, dtype_bytes: int = 2,
                  weight_bytes: Optional[int] = None) -> Tuple[int, int, int]:
    """The (Tc, Tj, Tk) the kernel picks with no explicit opts.

    ``weight_bytes`` mirrors the kernel's rule exactly: the default
    hidden tile is sized off the *streamed operand's* itemsize, so
    quantized weights fit proportionally larger Tk per VMEM block."""
    from repro.kernels.streamed_moe import DEFAULT_TOKEN_TILE, VMEM_BLOCK_BYTES
    wb = dtype_bytes if weight_bytes is None else weight_bytes
    Tc = min(DEFAULT_TOKEN_TILE, max(C, 1))
    Tk = _fit_tile(m, max(1, VMEM_BLOCK_BYTES // max(1, d * wb)))
    return Tc, d, Tk


def plan_kernel_tiles(E: int, C: int, d: int, m: int, activation: str,
                      profile: Optional[HardwareProfile] = None,
                      dtype_bytes: int = 2,
                      weight_bytes: Optional[int] = None) -> Dict[str, object]:
    """Score candidate (token_tile, dmodel_tile, dexpert_tile) and return
    the winner + its predicted time and VMEM footprint.

    The kernel-default tiling is always a candidate and wins ties, so the
    analytic level only departs from today's lowering when the model says
    the default genuinely loses (e.g. VMEM overflow forcing d_model
    tiling, or tiny C making a 128-row token tile mostly padding).
    ``weight_bytes`` makes the race quantization-aware: streamed weight
    blocks shrink, so larger hidden tiles fit the same VMEM budget.
    """
    profile = profile or HardwareProfile.detect()
    gated = activation == "swiglu"
    dTc, dTj, dTk = default_tiles(C, d, m, dtype_bytes, weight_bytes)

    tc_cands = sorted({dTc} | {t for t in (32, 64, 128, 256) if t <= max(C, 1)})
    tk_cands = sorted({dTk} | {t for t in {m, m // 2, m // 4} if t >= 1})
    tj_cands = sorted({dTj} | {t for t in {d, d // 2, d // 4} if t >= 1})

    best = None
    for Tc in tc_cands:
        for tj_req in tj_cands:
            Tj = _fit_tile(d, tj_req)
            for tk_req in tk_cands:
                Tk = _fit_tile(m, tk_req)
                sc = kernel_tile_cost(E, C, d, m, Tc, Tj, Tk, gated,
                                      profile, dtype_bytes, weight_bytes)
                fits = sc["vmem"] <= profile.vmem_bytes
                is_default = (Tc, Tj, Tk) == (dTc, dTj, dTk)
                # fitting candidates race on predicted time (default wins
                # ties); if nothing fits, minimize the overflow instead
                key = (not fits,
                       sc["t"] * (1.0 - 1e-6 * is_default) if fits
                       else sc["vmem"])
                if best is None or key < best[0]:
                    best = (key, (Tc, Tj, Tk), sc)
    (_, (Tc, Tj, Tk), sc) = best
    return {"token_tile": Tc,
            "dmodel_tile": None if Tj == d else Tj,
            "dexpert_tile": None if Tk == dTk else Tk,
            "predicted_s": sc["t"], "vmem_bytes": int(sc["vmem"]),
            "fits": sc["vmem"] <= profile.vmem_bytes}


# ---------------------------------------------------------------------------
# measured tile autotune (on-disk memoized)
# ---------------------------------------------------------------------------

def _repo_root() -> str:
    here = os.path.abspath(os.path.dirname(__file__))
    cand = os.path.dirname(os.path.dirname(os.path.dirname(here)))
    if os.path.exists(os.path.join(cand, "pyproject.toml")):
        return cand
    return os.getcwd()


def cache_dir() -> str:
    return os.environ.get("REPRO_AUTOTUNE_CACHE",
                          os.path.join(_repo_root(), "artifacts", "autotune"))


_MEASURED: Dict[str, dict] = {}
_CACHE_LOADED = False


def _cache_path() -> str:
    return os.path.join(cache_dir(), "kernel_tiles.json")


def _load_cache() -> None:
    global _CACHE_LOADED
    if _CACHE_LOADED:
        return
    _CACHE_LOADED = True
    try:
        with open(_cache_path()) as f:
            _MEASURED.update(json.load(f))
    except (OSError, ValueError):
        pass


def _save_cache() -> None:
    try:
        os.makedirs(cache_dir(), exist_ok=True)
        with open(_cache_path(), "w") as f:
            json.dump(_MEASURED, f, indent=1, sort_keys=True)
    except OSError:  # pragma: no cover — read-only checkout
        pass


def measured_kernel_tiles(E: int, C: int, d: int, m: int, activation: str,
                          dtype_bytes: int = 2, reps: int = 3,
                          profile: Optional[HardwareProfile] = None,
                          weight_bytes: Optional[int] = None) -> dict:
    """Time candidate tile lowerings of the streamed-MoE kernel once and
    memoize the winner (keyed by backend/jax-version/shape) under
    ``artifacts/autotune/kernel_tiles.json``.

    Each cache entry also records the XLA ``cost_analysis`` flops of the
    winning lowering (via ``launch.analysis.cost_dict``) next to the
    measured milliseconds, so predicted-vs-measured drift is inspectable.
    """
    import statistics
    import time

    import jax
    import jax.numpy as jnp

    from repro.kernels import ops as kops
    from repro.launch.analysis import cost_dict

    _load_cache()
    key = (f"{jax.default_backend()}/{jax.__version__}/"
           f"E{E}_C{C}_d{d}_m{m}_{activation}_b{dtype_bytes}"
           + (f"_w{weight_bytes}" if weight_bytes is not None else ""))
    if key in _MEASURED:
        return _MEASURED[key]

    analytic = plan_kernel_tiles(E, C, d, m, activation, profile,
                                 dtype_bytes, weight_bytes)
    cands: List[Dict[str, int]] = [{}]                    # kernel defaults
    opt = {k: v for k, v in analytic.items()
           if k in ("token_tile", "dmodel_tile", "dexpert_tile") and v}
    if opt:
        cands.append(opt)
    if m > 1:
        cands.append({"dexpert_tile": max(1, m // 2)})

    dt = jnp.float32 if dtype_bytes == 4 else jnp.bfloat16
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    xe = jax.random.normal(ks[0], (E, C, d), dt)
    wu = jax.random.normal(ks[1], (E, d, m), dt) * 0.1
    wd = jax.random.normal(ks[2], (E, m, d), dt) * 0.1
    wg = jax.random.normal(ks[3], (E, d, m), dt) * 0.1 \
        if activation == "swiglu" else None

    rows = []
    for opts in cands:
        def fn(xe, wg, wu, wd, _opts=opts):
            with kops.use_kernels(True):
                return kops.streamed_moe(xe, wg, wu, wd, activation, **_opts)
        jf = jax.jit(fn)
        try:
            compiled = jf.lower(xe, wg, wu, wd).compile()
            flops = float(cost_dict(compiled).get("flops", 0.0))
            jax.block_until_ready(jf(xe, wg, wu, wd))
            ts = []
            for _ in range(reps):
                t0 = time.perf_counter()
                jax.block_until_ready(jf(xe, wg, wu, wd))
                ts.append(time.perf_counter() - t0)
            rows.append({"opts": opts, "ms": statistics.median(ts) * 1e3,
                         "flops": flops})
        except Exception as e:  # pragma: no cover — candidate fails to lower
            rows.append({"opts": opts, "ms": float("inf"), "error": str(e)})

    best = min(rows, key=lambda r: r["ms"])
    entry = {"opts": best["opts"], "ms": best["ms"],
             "flops": best.get("flops", 0.0),
             "analytic_s": analytic["predicted_s"],
             "candidates": [{k: v for k, v in r.items() if k != "flops"}
                            for r in rows]}
    _MEASURED[key] = entry
    _save_cache()
    return entry


@functools.lru_cache(maxsize=4096)
def _kernel_opts_cached(E: int, C: int, d: int, m: int, activation: str,
                        dtype_bytes: int, level: str,
                        profile: HardwareProfile,
                        weight_bytes: Optional[int]
                        ) -> Tuple[Tuple[str, int], ...]:
    if level == "off":
        return ()
    if level == "measured":
        entry = measured_kernel_tiles(E, C, d, m, activation, dtype_bytes,
                                      profile=profile,
                                      weight_bytes=weight_bytes)
        return tuple(sorted((k, v) for k, v in entry["opts"].items() if v))
    tiles = plan_kernel_tiles(E, C, d, m, activation, profile, dtype_bytes,
                              weight_bytes)
    return tuple(sorted(
        (k, v) for k, v in tiles.items()
        if k in ("token_tile", "dmodel_tile", "dexpert_tile") and v))


def kernel_opts_for(E: int, C: int, d: int, m: int, activation: str,
                    dtype_bytes: int = 2, *, level: Optional[str] = None,
                    profile: Optional[HardwareProfile] = None,
                    weight_bytes: Optional[int] = None) -> Dict[str, int]:
    """Tile kwargs for one ``streamed_moe`` call shape under the ambient
    (or given) autotune level.  ``{}`` at level 'off' — kernel defaults.
    ``weight_bytes`` is the streamed weight byte width (quantized
    storage; ``None`` = ``dtype_bytes``)."""
    level = level or autotune_level()
    profile = profile or HardwareProfile.detect()
    return dict(_kernel_opts_cached(
        int(E), int(C), int(d), int(m), activation, int(dtype_bytes), level,
        profile, None if weight_bytes is None else int(weight_bytes)))


# ---------------------------------------------------------------------------
# the planner
# ---------------------------------------------------------------------------


def fallback_plan(B: int, S: int, P: int, micro_slices: int) -> Plan:
    """Zero-knowledge fallback: the original ``pick_mode`` heuristic —
    first feasible mode in stream > index > slice priority order — with
    the statically-configured micro-slice count and kernel-default tiles."""
    return Plan(mode=feasible_modes(B, S, P)[0], micro_slices=micro_slices,
                source="fallback")


@functools.lru_cache(maxsize=4096)
def _plan_moe_cached(B: int, S: int, d: int, E: int, de: int, top_k: int,
                     cf: float, n_mats: int, micro_cfg: int, P: int,
                     activation: str, profile: HardwareProfile,
                     dtype_bytes: int, level: str,
                     force_mode: Optional[str],
                     load: Optional[Tuple[float, ...]],
                     weight_bytes: Optional[int]) -> Plan:
    if level == "off" and force_mode is None:
        return fallback_plan(B, S, P, micro_cfg)

    feasible = feasible_modes(B, S, P)
    if force_mode is not None:
        if force_mode not in feasible:
            raise ValueError(f"mode {force_mode!r} infeasible for "
                             f"B={B} S={S} P={P} (feasible: {feasible})")
        feasible = (force_mode,)

    de_loc = max(1, de // P)
    best: Optional[Tuple[float, str, int, Dict[str, float]]] = None
    per_mode: Dict[str, float] = {}
    for mode in feasible:
        mode_best: Optional[Tuple[float, int]] = None
        micro_cands = _micro_candidates(de_loc, micro_cfg) \
            if mode in ("stream", "index") else [1]
        for M in micro_cands:
            c = mode_cost(mode, B, S, d, E, de, top_k, cf, n_mats, P,
                          profile, M, dtype_bytes, load, weight_bytes)
            if mode_best is None or c["total_s"] < mode_best[0]:
                mode_best = (c["total_s"], M)
        per_mode[mode] = mode_best[0]
        if best is None or mode_best[0] < best[0]:
            best = (mode_best[0], mode, mode_best[1], per_mode)
    total_s, mode, M, _ = best

    # tile selection for the winning plan's kernel shape
    T_loc = (B * S) // P if mode in ("stream", "index") else B * S
    C = _cap(max(1, T_loc), top_k, E, cf)
    m_step = max(1, de_loc // M) if mode in ("stream", "index") else de_loc
    if level == "measured":
        entry = measured_kernel_tiles(E, C, d, m_step, activation,
                                      dtype_bytes, profile=profile,
                                      weight_bytes=weight_bytes)
        opts = dict(entry["opts"])
        tiles = plan_kernel_tiles(E, C, d, m_step, activation, profile,
                                  dtype_bytes, weight_bytes)
        vmem = tiles["vmem_bytes"]
        source = "measured"
    else:
        tiles = plan_kernel_tiles(E, C, d, m_step, activation, profile,
                                  dtype_bytes, weight_bytes)
        opts = {k: v for k, v in tiles.items()
                if k in ("token_tile", "dmodel_tile", "dexpert_tile")}
        vmem = tiles["vmem_bytes"]
        source = "analytic"

    from repro.kernels.streamed_moe import DEFAULT_TOKEN_TILE
    return Plan(mode=mode, micro_slices=M,
                token_tile=opts.get("token_tile", DEFAULT_TOKEN_TILE),
                dmodel_tile=opts.get("dmodel_tile"),
                dexpert_tile=opts.get("dexpert_tile"),
                predicted_s=total_s, vmem_bytes=vmem,
                per_mode_s=tuple(sorted(per_mode.items())),
                source=source if force_mode is None else "forced")


def plan_moe(B: int, S: int, d_model: int, moe, activation: str, P: int,
             *, profile: Optional[HardwareProfile] = None,
             dtype_bytes: int = 2, level: Optional[str] = None,
             mode: Optional[str] = None,
             load: Optional[Tuple[float, ...]] = None,
             weight_bytes: Optional[int] = None) -> Plan:
    """Score all feasible (mode, micro_slices, tiles) and return the winner.

    ``moe`` is a :class:`repro.configs.base.MoEConfig`; ``P`` the model-axis
    size.  ``mode`` forces a specific execution mode (still optimizing the
    remaining knobs) — used by benchmarks and the parity tests.  ``load``
    conditions the cost model on a normalized per-expert load vector
    (dynamic trajectory scheduling; ``None`` = the uniform shape-only
    model).  ``weight_bytes`` is the streamed expert-weight byte width
    (quantized storage, ``kernels.quant``; ``None`` = ``dtype_bytes``) —
    it scales every weight ring/DDR term and the tile race.  Pure Python
    — call freely at trace time; results are memoized.
    """
    level = level or autotune_level()
    profile = profile or HardwareProfile.detect()
    n_mats = 3 if activation == "swiglu" else 2
    if load is not None:
        load = tuple(float(v) for v in load)
    return _plan_moe_cached(int(B), int(S), int(d_model),
                            int(moe.num_experts), int(moe.d_expert),
                            int(moe.top_k), float(moe.capacity_factor),
                            n_mats, int(moe.micro_slices), int(P),
                            activation, profile, int(dtype_bytes), level,
                            mode, load,
                            None if weight_bytes is None else int(weight_bytes))


_PICK_MODE_WARNED = False


def pick_mode(B: int, S: int, P_: int) -> str:
    """Deprecated: the zero-knowledge mode heuristic.  The ``level='off'``
    fallback now routes through the strategy registry
    (``repro.core.strategy`` -> :func:`fallback_plan`); new callers should
    use :func:`plan_moe` and read ``plan.mode``.  Warns once per process."""
    global _PICK_MODE_WARNED
    if not _PICK_MODE_WARNED:
        _PICK_MODE_WARNED = True
        warnings.warn("core.autotune.pick_mode / core.fse_dp.pick_mode is "
                      "deprecated; use autotune.plan_moe(...).mode or the "
                      "repro.core.strategy registry",
                      DeprecationWarning, stacklevel=2)
    return fallback_plan(B, S, P_, 1).mode
