"""Unified MoE execution-strategy API: one registry, one spec.

The paper's thesis is that expert execution should be *chosen at
runtime along dynamic trajectories*.  This module is the surface that
makes the choice a first-class object instead of an if/elif chain over
string ``impl`` names:

* :class:`MoEStrategy` — the protocol every execution family
  implements: ``plan(ctx) -> Plan`` (pure, trace-time) and
  ``execute(params, x, moe, activation, plan) -> (y, aux)``, where
  ``execute`` is the family's realization of the shared four-stage
  pipeline **route -> schedule -> dispatch -> combine**
  (``repro.core.trajectory``): routing is computed once (or accepted
  precomputed via ``routing=``), the schedule stage turns the routing's
  ``expert_token_counts`` into an expert trajectory when
  ``ExecutionSpec.schedule == "dynamic"`` (or consumes a host-built
  ``trajectory.Schedule``), and dispatch/combine bracket the family's
  dataflow (ring stream, all-to-all, psum, capacity gather);
* a named **registry** (:func:`register` / :func:`get_strategy`):
  ``fse_dp`` (the paper's expert streaming), ``ep`` / ``tp`` (the
  baselines), ``capacity`` / ``dense`` (single-device paths),
  ``hybrid`` (two-tier hot/cold placement on heterogeneous hardware
  with a near-memory tier), and ``auto`` — a cross-family planner that
  scores the EP and TP cost curves *alongside* the three FSE-DP modes
  (and ``hybrid`` when the profile has an NDP tier) so the winning
  family, not just the winning FSE-DP mode, is picked per shape
  (validated against ``sim.modes.rank_families``);
* :class:`ExecutionSpec` — a frozen, JSON-round-trippable configuration
  object (strategy name, per-phase and per-layer overrides, autotune
  level, kernels on/off, sorted dispatch) that replaces ``moe.impl``
  strings, ``ServeConfig.moe_impl``/``autotune``, and the ad-hoc
  context toggles at every call site.  ``models.moe.moe_block`` is a
  thin registry lookup over it.

Future strategies (NDP offload, cacheless on-demand loading,
multi-chiplet topologies) plug in with ``@register("name")`` — no
caller changes.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import json
import warnings
from dataclasses import dataclass
from typing import Any, Dict, Optional, Protocol, Tuple, runtime_checkable

from repro.configs.base import MoEConfig
from . import autotune
from .autotune import HardwareProfile, Plan

PHASES = ("train", "prefill", "decode")

# cross-family candidates of the ``auto`` planner, in tie-break priority
# order (ties go to the earlier family — deterministic, mirrored by the
# simulator referee ``sim.modes.rank_families``).  BASE_FAMILIES race on
# any hardware; ``hybrid`` (two-tier hot/cold placement) joins only when
# the profile carries a near-memory tier (``HardwareProfile.ndp_flops``),
# appended last so the homogeneous trio keeps its tie-break priority.
BASE_FAMILIES = ("fse_dp", "ep", "tp")
FAMILIES = BASE_FAMILIES + ("hybrid",)


def default_hot(E: int) -> int:
    """Fast-tier expert count when nothing better is known: the top
    quartile of experts by load (≥1) — the static top-N baseline the
    dynamic EMA repartition is measured against."""
    return max(1, E // 4)

# (B, S, E, d_expert, P) cross-family validation sweep shared by
# tests/test_strategy.py and benchmarks: tiny-token shapes where TP
# (weights stationary, everything replicated) is the only dataflow that
# lowers cheaply, decode shapes where EP's token-side all-to-all beats
# moving weights, and prefill shapes with E % P != 0 (EP cannot split
# the experts; streaming d_expert slices can) where FSE-DP wins.  Each
# family wins at least once.
FAMILY_SWEEP: Tuple[Tuple[int, int, int, int, int], ...] = (
    (1, 1, 16, 512, 4), (1, 2, 64, 256, 8), (2, 1, 16, 768, 4),
    (8, 1, 16, 512, 4), (32, 1, 16, 512, 4), (16, 1, 8, 1024, 2),
    (512, 1, 32, 256, 8), (1024, 2, 64, 256, 8), (4, 16, 8, 256, 4),
    (1, 128, 16, 512, 4),
    (4, 512, 12, 512, 8), (1, 512, 12, 768, 8), (2, 1024, 18, 512, 4),
    (2, 2048, 18, 768, 4),
)

# (B, S, E, d_expert, P, zipf_s) two-tier validation sweep on NDP
# hardware (``sim.hardware.with_ndp(scaled(...))``), shared by
# tests/test_hybrid.py and benchmarks/jax_moe_strategies.py: low-batch
# decode where offloading cold experts near memory wins (hybrid),
# batch-heavy decode where the token all-to-all wins (ep), and long
# prefill where hybrid's un-sharded dispatch tax bites (fse_dp).  Each
# of hybrid/ep/fse_dp wins at least one point; zipf_s > 0 points load
# the race with a rank-permuted Zipf vector (``sim.workload``, seed 0).
HYBRID_SWEEP: Tuple[Tuple[int, int, int, int, int, float], ...] = (
    (1, 1, 64, 1408, 4, 1.2), (4, 1, 64, 1408, 4, 1.2),
    (2, 1, 128, 768, 4, 1.2), (32, 1, 16, 512, 4, 0.0),
    (1, 2, 64, 256, 8, 1.2), (16, 1, 8, 1024, 2, 0.0),
    (512, 1, 32, 256, 8, 0.0), (1024, 2, 64, 256, 8, 1.2),
    (4, 512, 16, 512, 4, 0.0),
    (2, 1024, 18, 512, 4, 0.0), (2, 2048, 18, 768, 4, 1.2),
)


# ---------------------------------------------------------------------------
# ExecutionSpec — the single configuration object
# ---------------------------------------------------------------------------


def _freeze_overrides(overrides) -> Tuple[Tuple[int, str], ...]:
    if not overrides:
        return ()
    if isinstance(overrides, dict):
        items = overrides.items()
    else:
        items = tuple(overrides)
    return tuple(sorted((int(k), str(v)) for k, v in items))


@dataclass(frozen=True)
class ExecutionSpec:
    """One serializable description of how MoE layers execute.

    Resolution order at a call site: ``layer_overrides[layer]`` >
    per-phase field (``prefill`` / ``decode`` / ``train``) >
    ``strategy``.  ``autotune`` / ``use_kernels`` / ``sorted_dispatch``
    scope the corresponding context toggles around the executed block
    (``None`` inherits the ambient setting).
    """

    strategy: str = "auto"
    prefill: Optional[str] = None
    decode: Optional[str] = None
    train: Optional[str] = None
    layer_overrides: Tuple[Tuple[int, str], ...] = ()
    autotune: Optional[str] = None          # off | analytic | measured
    schedule: Optional[str] = None          # static | dynamic (None=static)
    use_kernels: Optional[bool] = None      # None = ambient kernels toggle
    sorted_dispatch: Optional[bool] = None  # None = ambient dispatch mode
    weight_dtype: Optional[str] = None      # fp32 | bf16 | int8 | fp8
                                            # (streamed expert-weight format,
                                            # kernels.quant; None = params
                                            # as-is)

    def __post_init__(self):
        object.__setattr__(self, "layer_overrides",
                           _freeze_overrides(self.layer_overrides))
        if self.autotune not in (None, "off", "analytic", "measured"):
            raise ValueError(f"unknown autotune level {self.autotune!r}")
        if self.schedule not in (None, "static", "dynamic"):
            raise ValueError(f"unknown schedule policy {self.schedule!r} "
                             f"(want 'static' or 'dynamic')")
        from repro.kernels import quant
        quant.check_weight_dtype(self.weight_dtype)

    # ---- resolution ---------------------------------------------------

    def resolve(self, phase: Optional[str] = None,
                layer: Optional[int] = None) -> str:
        """Strategy name for one call site."""
        if layer is not None:
            for lyr, name in self.layer_overrides:
                if lyr == layer:
                    return name
        if phase is not None:
            if phase not in PHASES:
                raise ValueError(f"unknown phase {phase!r} (want {PHASES})")
            override = getattr(self, phase)
            if override:
                return override
        return self.strategy

    def strategies_used(self) -> Tuple[str, ...]:
        """Every strategy name this spec can resolve to (for validation)."""
        names = {self.strategy}
        names |= {getattr(self, p) for p in PHASES if getattr(self, p)}
        names |= {name for _, name in self.layer_overrides}
        return tuple(sorted(names))

    def validate(self) -> "ExecutionSpec":
        """Raise if any referenced strategy is not registered."""
        for name in self.strategies_used():
            get_strategy(name)
        return self

    # ---- context scoping ---------------------------------------------

    @contextlib.contextmanager
    def scope(self):
        """Apply the spec's autotune / kernels / dispatch toggles."""
        with contextlib.ExitStack() as stack:
            if self.autotune is not None:
                stack.enter_context(autotune.use_autotune(self.autotune))
            if self.use_kernels is not None:
                from repro.kernels import ops as kops
                stack.enter_context(kops.use_kernels(self.use_kernels))
            if self.sorted_dispatch is not None:
                from repro.models.moe import use_sorted_dispatch
                stack.enter_context(use_sorted_dispatch(self.sorted_dispatch))
            if self.weight_dtype is not None:
                from repro.kernels import quant
                stack.enter_context(quant.use_weight_dtype(self.weight_dtype))
            yield self

    # ---- (de)serialization -------------------------------------------

    def to_dict(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {"strategy": self.strategy}
        for p in PHASES:
            if getattr(self, p) is not None:
                out[p] = getattr(self, p)
        if self.layer_overrides:
            out["layer_overrides"] = {str(k): v
                                      for k, v in self.layer_overrides}
        for f in ("autotune", "schedule", "use_kernels", "sorted_dispatch",
                  "weight_dtype"):
            if getattr(self, f) is not None:
                out[f] = getattr(self, f)
        return out

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "ExecutionSpec":
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"unknown ExecutionSpec fields {sorted(unknown)} "
                             f"(known: {sorted(known)})")
        return cls(**d)

    def to_json(self, **kw) -> str:
        return json.dumps(self.to_dict(), sort_keys=True, **kw)

    @classmethod
    def from_json(cls, s: str) -> "ExecutionSpec":
        return cls.from_dict(json.loads(s))

    @classmethod
    def load(cls, path: str) -> "ExecutionSpec":
        with open(path) as f:
            return cls.from_dict(json.load(f))

    @classmethod
    def coerce(cls, value, default: str = "auto") -> "ExecutionSpec":
        """Build a spec from anything callers pass: ``None`` (use
        ``default``), a strategy name, a dict, or a spec."""
        if value is None:
            return cls(strategy=default)
        if isinstance(value, cls):
            return value
        if isinstance(value, str):
            return cls(strategy=value)
        if isinstance(value, dict):
            if "strategy" not in value:
                value = dict(value, strategy=default)
            return cls.from_dict(value)
        raise TypeError(f"cannot coerce {type(value).__name__} to "
                        f"ExecutionSpec")


# ---------------------------------------------------------------------------
# strategy protocol + registry
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class StrategyContext:
    """Static shape/config facts a strategy needs to plan one call."""

    B: int                   # per-model-group batch (global B / data axes)
    S: int
    d_model: int
    moe: MoEConfig
    activation: str
    P: int = 1               # model-axis size
    dtype_bytes: int = 2     # activation bytes per element
    level: Optional[str] = None
    profile: Optional[HardwareProfile] = None
    load: Optional[Tuple[float, ...]] = None  # per-expert load shares
    weight_bytes: Optional[int] = None  # streamed expert-weight bytes/param
                                        # (None = dtype_bytes)

    @classmethod
    def from_inputs(cls, x, moe: MoEConfig, activation: str,
                    axis: str = "model", *,
                    load: Optional[Tuple[float, ...]] = None
                    ) -> "StrategyContext":
        import jax.numpy as jnp
        from repro.parallel import meshctx
        mesh = meshctx.get_mesh()
        P_ = 1 if mesh is None or axis not in mesh.axis_names \
            else mesh.shape[axis]
        B, S, d = x.shape
        if mesh is not None:
            batch = meshctx.batch_axes(mesh, axis)
            bsz = 1
            for a in batch:
                bsz *= mesh.shape[a]
            if batch and B % bsz == 0:
                B //= bsz
        from repro.kernels import quant
        return cls(B=int(B), S=int(S), d_model=int(d), moe=moe,
                   activation=activation, P=int(P_),
                   dtype_bytes=jnp.dtype(x.dtype).itemsize, load=load,
                   weight_bytes=quant.weight_bytes())


@runtime_checkable
class MoEStrategy(Protocol):
    """One pluggable execution family."""

    name: str

    def plan(self, ctx: StrategyContext) -> Plan:
        """Trace-time decision (pure Python, memoizable)."""
        ...

    def execute(self, params, x, moe: MoEConfig, activation: str,
                plan: Optional[Plan] = None, *, axis: str = "model",
                routing=None, schedule=None):
        """x: (B, S, d) global. Returns ``(y, aux)``.

        One route -> schedule -> dispatch -> combine pass: ``routing``
        pre-computes the route stage (single-device strategies only),
        ``schedule`` the schedule stage (``trajectory.Schedule``)."""
        ...


_REGISTRY: Dict[str, MoEStrategy] = {}


def register(name: str):
    """Class decorator: instantiate and register an execution strategy."""
    def deco(cls):
        cls.name = name
        _REGISTRY[name] = cls()
        return cls
    return deco


def get_strategy(name: str) -> MoEStrategy:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown MoE strategy {name!r}; "
                       f"registered: {available()}") from None


def available() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def execute(name_or_spec, params, x, moe: MoEConfig, activation: str, *,
            plan: Optional[Plan] = None, axis: str = "model",
            phase: Optional[str] = None, layer: Optional[int] = None,
            routing=None, schedule=None):
    """Functional entry: run one MoE layer under a strategy name or an
    :class:`ExecutionSpec`.  Returns ``(y, aux)``.

    ``routing`` / ``schedule`` pre-compute the pipeline's route and
    schedule stages; with neither, a spec whose ``schedule`` field is
    ``"dynamic"`` derives the trajectory in-graph."""
    spec = ExecutionSpec.coerce(name_or_spec)
    name = spec.resolve(phase=phase, layer=layer)
    if schedule is None and spec.schedule == "dynamic":
        from . import trajectory
        schedule = trajectory.DYNAMIC
    with spec.scope():
        return get_strategy(name).execute(params, x, moe, activation, plan,
                                          axis=axis, routing=routing,
                                          schedule=schedule)


_ENTRY_WARNED: set = set()


def warn_deprecated_entry(old: str, name: str) -> None:
    """One-shot DeprecationWarning for a legacy ``*_moe_3d`` entry point."""
    if old in _ENTRY_WARNED:
        return
    _ENTRY_WARNED.add(old)
    warnings.warn(f"{old} is deprecated; use repro.core.strategy."
                  f"execute({name!r}, ...) or moe_block(spec=...)",
                  DeprecationWarning, stacklevel=3)


# ---------------------------------------------------------------------------
# cross-family cost curves + the auto planner
# ---------------------------------------------------------------------------


def ep_feasible(B: int, S: int, E: int, P: int) -> bool:
    """EP lowers when experts split evenly and tokens can seq- or
    batch-shard over the model axis (``core.baselines.moe_ep``)."""
    return P > 1 and E % P == 0 and (S % P == 0 or B % P == 0)


def family_costs(B: int, S: int, d_model: int, moe: MoEConfig,
                 activation: str, P: int, *,
                 profile: Optional[HardwareProfile] = None,
                 dtype_bytes: int = 2,
                 load: Optional[Tuple[float, ...]] = None,
                 weight_bytes: Optional[int] = None) -> Dict[str, float]:
    """Predicted seconds per candidate family for one MoE layer.

    ``load`` conditions every family's cost curve on a normalized
    per-expert load vector (``None`` = the uniform shape-only model —
    bit-identical to the pre-load behavior).

    ``fse_dp`` is scored as the best *ring* (streaming) schedule —
    stream/index with per-mode-optimized micro-slices.  When no ring
    layout lowers for the shape, the fse_dp family leaves the race:
    its degraded slice dataflow is exactly the TP dataflow, which the
    ``tp`` entry already owns (a spec-forced ``fse_dp`` still executes
    via the slice fallback).  ``tp`` is the weights-stationary cost
    curve; ``ep`` the all-to-all cost curve when it can lower (experts
    split evenly, tokens seq- or batch-shardable).
    """
    profile = profile or HardwareProfile.detect()
    n_mats = 3 if activation == "swiglu" else 2
    E, de = moe.num_experts, moe.d_expert
    k, cf = moe.top_k, moe.capacity_factor
    de_loc = max(1, de // P)
    out: Dict[str, float] = {}

    ring = [m for m in autotune.feasible_modes(B, S, P) if m != "slice"]
    if ring:
        out["fse_dp"] = min(
            autotune.mode_cost(m, B, S, d_model, E, de, k, cf, n_mats, P,
                               profile, M, dtype_bytes, load,
                               weight_bytes)["total_s"]
            for m in ring
            for M in autotune._micro_candidates(de_loc, moe.micro_slices))
    if ep_feasible(B, S, E, P):
        out["ep"] = autotune.ep_cost(B, S, d_model, E, de, k, cf, n_mats,
                                     P, profile, dtype_bytes, load,
                                     weight_bytes)["total_s"]
    out["tp"] = autotune.mode_cost("slice", B, S, d_model, E, de, k, cf,
                                   n_mats, P, profile, 1,
                                   dtype_bytes, load,
                                   weight_bytes)["total_s"]
    if profile.ndp_flops and profile.ndp_bw:
        out["hybrid"] = autotune.hybrid_cost(
            B, S, d_model, E, de, k, cf, n_mats, P, profile,
            dtype_bytes, load, weight_bytes)["total_s"]
    return out


def pick_family(costs: Dict[str, float]) -> str:
    """Deterministic argmin in FAMILIES priority order (ties -> earlier)."""
    return min((f for f in FAMILIES if f in costs), key=lambda f: costs[f])


@functools.lru_cache(maxsize=4096)
def _plan_family_cached(B: int, S: int, d_model: int, moe: MoEConfig,
                        activation: str, P: int,
                        profile: Optional[HardwareProfile],
                        dtype_bytes: int, level: str,
                        load: Optional[Tuple[float, ...]],
                        weight_bytes: Optional[int]) -> Plan:
    if P == 1:
        return Plan(mode="capacity", family="capacity", micro_slices=1,
                    source="fallback")
    if level == "off":
        # zero-knowledge fallback: the registry default family with the
        # legacy static heuristic (no pick_mode call — routed through
        # fallback_plan, which the deprecated pick_mode also wraps)
        return autotune.fallback_plan(B, S, P, moe.micro_slices)
    costs = family_costs(B, S, d_model, moe, activation, P,
                         profile=profile, dtype_bytes=dtype_bytes, load=load,
                         weight_bytes=weight_bytes)
    family = pick_family(costs)
    per_family = tuple(sorted((f, float(s)) for f, s in costs.items()))
    if family == "fse_dp":
        plan = autotune.plan_moe(B, S, d_model, moe, activation, P,
                                 profile=profile, dtype_bytes=dtype_bytes,
                                 level=level, load=load,
                                 weight_bytes=weight_bytes)
        return dataclasses.replace(plan, per_mode_s=plan.per_mode_s
                                   + per_family)
    if family == "hybrid":
        c = autotune.hybrid_cost(
            B, S, d_model, moe.num_experts, moe.d_expert, moe.top_k,
            moe.capacity_factor, 3 if activation == "swiglu" else 2, P,
            profile or HardwareProfile.detect(), dtype_bytes, load,
            weight_bytes)
        return Plan(mode="hybrid", family="hybrid", micro_slices=1,
                    predicted_s=costs["hybrid"], per_mode_s=per_family,
                    source="analytic", hot_experts=int(c["hot_n"]))
    return Plan(mode=family, family=family, micro_slices=1,
                predicted_s=costs[family], per_mode_s=per_family,
                source="analytic")


def plan_family(B: int, S: int, d_model: int, moe: MoEConfig,
                activation: str, P: int, *,
                profile: Optional[HardwareProfile] = None,
                dtype_bytes: int = 2,
                level: Optional[str] = None,
                load: Optional[Tuple[float, ...]] = None,
                weight_bytes: Optional[int] = None) -> Plan:
    """Cross-family planner: score EP and TP cost curves alongside the
    FSE-DP ring modes and return the winning family's Plan.  ``load``
    conditions the race on an observed per-expert load vector (dynamic
    trajectory re-planning); ``weight_bytes`` on the streamed
    expert-weight byte width (quantized storage).  Pure Python — call
    freely at trace time; memoized."""
    level = level or autotune.autotune_level()
    if load is not None:
        load = tuple(float(v) for v in load)
    return _plan_family_cached(int(B), int(S), int(d_model), moe,
                               activation, int(P), profile,
                               int(dtype_bytes), level, load,
                               None if weight_bytes is None
                               else int(weight_bytes))


# ---------------------------------------------------------------------------
# the built-in strategies
# ---------------------------------------------------------------------------


class _SingleDevice:
    """Shared machinery for the global-routing single-device paths.

    The pipeline stages are explicit here: :meth:`route` computes (or
    accepts) the Routing, the executors hand the schedule stage down to
    ``models.moe`` (which derives the trajectory from the routing's
    counts when the schedule is dynamic), and dispatch/combine are the
    capacity/dense dataflows in ``models.moe``.
    """

    def plan(self, ctx: StrategyContext) -> Plan:
        return Plan(mode=self.name, family=self.name, micro_slices=1,
                    source="analytic")

    def route(self, params, x, moe, routing=None):
        from repro.core import gating
        x2d = x.reshape(-1, x.shape[-1])
        if routing is None:
            routing = gating.route(params["router"], x2d, top_k=moe.top_k)
        return x2d, routing

    # kept for any external callers of the old private helper
    _route = route


@register("dense")
class DenseStrategy(_SingleDevice):
    """Every expert on every token, masked combine (oracle; tests)."""

    def execute(self, params, x, moe, activation, plan=None, *,
                axis="model", routing=None, schedule=None):
        from repro.core import gating
        from repro.models import moe as moe_mod
        x2d, routing = self.route(params, x, moe, routing)
        y = moe_mod.moe_dense(params, x2d, routing, activation,
                              schedule=schedule)
        return (y.reshape(x.shape),
                gating.aux_load_balance_loss(routing, moe.num_experts))


@register("capacity")
class CapacityStrategy(_SingleDevice):
    """Switch-style capacity dispatch (efficient single-device XLA)."""

    def execute(self, params, x, moe, activation, plan=None, *,
                axis="model", routing=None, schedule=None):
        from repro.core import gating
        from repro.models import moe as moe_mod
        x2d, routing = self.route(params, x, moe, routing)
        y = moe_mod.moe_capacity(params, x2d, routing, moe, activation,
                                 schedule=schedule)
        return (y.reshape(x.shape),
                gating.aux_load_balance_loss(routing, moe.num_experts))


@register("fse_dp")
class FseDpStrategy:
    """The paper's expert streaming (ring ppermute, repro.core.fse_dp)."""

    def plan(self, ctx: StrategyContext) -> Plan:
        if ctx.P == 1:
            return Plan(mode="capacity", family="capacity", micro_slices=1,
                        source="fallback")
        return autotune.plan_moe(ctx.B, ctx.S, ctx.d_model, ctx.moe,
                                 ctx.activation, ctx.P,
                                 profile=ctx.profile,
                                 dtype_bytes=ctx.dtype_bytes,
                                 level=ctx.level, load=ctx.load,
                                 weight_bytes=ctx.weight_bytes)

    def execute(self, params, x, moe, activation, plan=None, *,
                axis="model", routing=None, schedule=None):
        from repro.core import fse_dp
        return fse_dp.moe_fse_dp(params, x, moe, activation, axis=axis,
                                 plan=plan, routing=routing,
                                 schedule=schedule)


@register("ep")
class EpStrategy:
    """Expert parallelism: all_to_all token exchange to expert owners."""

    def plan(self, ctx: StrategyContext) -> Plan:
        if ctx.P == 1 or not ep_feasible(ctx.B, ctx.S,
                                         ctx.moe.num_experts, ctx.P):
            return get_strategy("fse_dp").plan(ctx)
        profile = ctx.profile or HardwareProfile.detect()
        n_mats = 3 if ctx.activation == "swiglu" else 2
        c = autotune.ep_cost(ctx.B, ctx.S, ctx.d_model,
                             ctx.moe.num_experts, ctx.moe.d_expert,
                             ctx.moe.top_k, ctx.moe.capacity_factor,
                             n_mats, ctx.P, profile, ctx.dtype_bytes,
                             ctx.load, ctx.weight_bytes)
        return Plan(mode="ep", family="ep", micro_slices=1,
                    predicted_s=c["total_s"], source="analytic")

    def execute(self, params, x, moe, activation, plan=None, *,
                axis="model", routing=None, schedule=None):
        from repro.core import baselines
        return baselines.moe_ep(params, x, moe, activation, axis=axis,
                                routing=routing, schedule=schedule)


@register("tp")
class TpStrategy:
    """Tensor parallelism: d_expert sharded, tokens replicated, psum."""

    def plan(self, ctx: StrategyContext) -> Plan:
        if ctx.P == 1:
            return get_strategy("fse_dp").plan(ctx)
        profile = ctx.profile or HardwareProfile.detect()
        n_mats = 3 if ctx.activation == "swiglu" else 2
        c = autotune.mode_cost("slice", ctx.B, ctx.S, ctx.d_model,
                               ctx.moe.num_experts, ctx.moe.d_expert,
                               ctx.moe.top_k, ctx.moe.capacity_factor,
                               n_mats, ctx.P, profile, 1, ctx.dtype_bytes,
                               ctx.load, ctx.weight_bytes)
        return Plan(mode="tp", family="tp", micro_slices=1,
                    predicted_s=c["total_s"], source="analytic")

    def execute(self, params, x, moe, activation, plan=None, *,
                axis="model", routing=None, schedule=None):
        from repro.core import baselines
        return baselines.moe_tp(params, x, moe, activation, axis=axis,
                                routing=routing, schedule=schedule)


@register("hybrid")
class HybridStrategy(_SingleDevice):
    """Two-tier hot/cold placement: hot experts stream through the fast
    chiplet array, cold experts execute in place on the near-memory tier
    (``HardwareConfig.ndp``); the layer finishes at ``max`` of the
    tiers.  The tier split is a *placement* decision — it changes where
    experts run, never the result — so execution partitions the expert
    trajectory into a hot prefix and a cold tail and is bit-identical
    to the single-tier capacity path (tests/test_hybrid.py)."""

    def plan(self, ctx: StrategyContext) -> Plan:
        profile = ctx.profile or HardwareProfile.detect()
        E = ctx.moe.num_experts
        if not (profile.ndp_flops and profile.ndp_bw):
            # homogeneous hardware: placement-only plan, static top-N
            return Plan(mode="hybrid", family="hybrid", micro_slices=1,
                        source="fallback", hot_experts=default_hot(E))
        n_mats = 3 if ctx.activation == "swiglu" else 2
        c = autotune.hybrid_cost(ctx.B, ctx.S, ctx.d_model, E,
                                 ctx.moe.d_expert, ctx.moe.top_k,
                                 ctx.moe.capacity_factor, n_mats, ctx.P,
                                 profile, ctx.dtype_bytes, ctx.load,
                                 ctx.weight_bytes)
        return Plan(mode="hybrid", family="hybrid", micro_slices=1,
                    predicted_s=c["total_s"], source="analytic",
                    hot_experts=int(c["hot_n"]))

    def execute(self, params, x, moe, activation, plan=None, *,
                axis="model", routing=None, schedule=None):
        from repro.parallel import meshctx
        mesh = meshctx.get_mesh()
        if mesh is not None and axis in mesh.axis_names \
                and mesh.shape[axis] > 1:
            # under a model mesh the hot tier's flow IS the FSE-DP ring;
            # the tier split doesn't map to an SPMD axis, so delegate
            return get_strategy("fse_dp").execute(
                params, x, moe, activation, None, axis=axis,
                routing=routing, schedule=schedule)
        from repro.core import gating
        from repro.models import moe as moe_mod
        x2d, routing = self.route(params, x, moe, routing)
        if plan is None and schedule is not None:
            plan = schedule.plan
        H = plan.hot_experts if plan is not None \
            and plan.hot_experts is not None \
            else default_hot(moe.num_experts)
        y = moe_mod.moe_hybrid(params, x2d, routing, moe, activation,
                               hot_experts=H, schedule=schedule)
        return (y.reshape(x.shape),
                gating.aux_load_balance_loss(routing, moe.num_experts))


@register("auto")
class AutoStrategy:
    """Cross-family planner: EP / TP cost curves scored alongside the
    FSE-DP ring modes; dispatches to the winning family's strategy."""

    def plan(self, ctx: StrategyContext) -> Plan:
        return plan_family(ctx.B, ctx.S, ctx.d_model, ctx.moe,
                           ctx.activation, ctx.P, profile=ctx.profile,
                           dtype_bytes=ctx.dtype_bytes, level=ctx.level,
                           load=ctx.load, weight_bytes=ctx.weight_bytes)

    def execute(self, params, x, moe, activation, plan=None, *,
                axis="model", routing=None, schedule=None):
        load = None if schedule is None else schedule.load
        ctx = StrategyContext.from_inputs(x, moe, activation, axis, load=load)
        if ctx.P == 1:
            return get_strategy("capacity").execute(params, x, moe,
                                                    activation, axis=axis,
                                                    routing=routing,
                                                    schedule=schedule)
        plan = plan or (schedule.plan if schedule is not None
                        and schedule.plan is not None else None) \
            or self.plan(ctx)
        family = plan.family
        inner = plan if family == "fse_dp" else None
        return get_strategy(family).execute(params, x, moe, activation,
                                            inner, axis=axis,
                                            routing=routing,
                                            schedule=schedule)
