"""Baseline distributed MoE strategies the paper compares against.

EP — expert parallelism (the de-facto baseline, paper §VI-A): each
device on the ``model`` axis *owns* ``E/P`` full experts; tokens are
routed to the owning device via ``all_to_all`` and routed back after
expert compute.  Token buffers are capacity-bounded, so skewed routing
drops tokens (or forces a large capacity factor) — the long-tail
failure mode the paper profiles.

TP — tensor parallelism: every expert's ``d_expert`` is sharded, tokens
are **replicated** on the model axis, partial outputs all-reduced
(the paper's critique: token duplication).

DP (replicated experts) exists only as an accounting mode in the
benchmarks — it needs no code beyond unsharded weights.

Both baselines dispatch their expert GEMMs through
``fse_dp._expert_partial`` with no explicit tile opts, which routes to
``kernels.ops.streamed_moe_autotuned`` — the same cost-model tile
scheduler (``core.autotune``) the FSE-DP modes use, so kernel-level
comparisons between strategies are tile-for-tile fair.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import MoEConfig
from repro.parallel import meshctx
from . import gating
from .fse_dp import _expert_partial, _route, shard_map, pmean_all


def _capacity(T_loc: int, moe: MoEConfig) -> int:
    return moe.capacity_rows(T_loc)


def _local_trajectory(schedule, counts_fn):
    """Schedule stage for the baseline bodies: the local expert-axis
    trajectory permutation, or ``None`` for static (untouched path)."""
    from . import trajectory
    return trajectory.resolve_order(schedule, counts_fn)


# ---------------------------------------------------------------------------
# EP — all-to-all dispatch to expert owners
# ---------------------------------------------------------------------------

def _local_ep(x, wr, w_g, w_u, w_d, *, moe, activation, axis, P_, pm_axes,
              schedule=None):
    """x: (B_loc, S_loc, d) seq-sharded. w_*: (E_loc, d, de) expert-sharded.

    Pipeline: route local rows -> schedule (dynamic: a trajectory over
    this rank's *owned* experts, ordered by the psum'd global gating
    counts) -> all-to-all dispatch -> grouped FFN -> all-to-all return
    -> combine.  The trajectory permutes the owned-expert batch axis
    around the FFN only, so outputs are bit-identical to static."""
    from repro.models.moe import dispatch_masks
    from . import trajectory
    B, S, d = x.shape
    E = moe.num_experts
    E_loc = E // P_
    x2d = x.reshape(B * S, d)
    T_loc = x2d.shape[0]
    C = _capacity(T_loc, moe)

    routing = _route(wr, x2d, moe)

    def _owned_counts():
        counts = jax.lax.psum(gating.expert_token_counts(routing), axis)
        r = jax.lax.axis_index(axis)
        return jax.lax.dynamic_slice_in_dim(counts, r * E_loc, E_loc, 0)

    # a host-built Schedule.order indexes GLOBAL experts; this body
    # schedules its owned E_loc shard, so a dynamic schedule always
    # derives the local trajectory in-graph from the psum'd counts
    order = None
    if schedule is not None and schedule.dynamic:
        order = trajectory.traced_order(_owned_counts())
    dispatch, combine = dispatch_masks(routing, T_loc, E, C)          # (T,E,C)
    xsend = jnp.einsum("tec,td->ecd", dispatch.astype(x2d.dtype), x2d)  # (E,C,d)
    xsend = xsend.reshape(P_, E_loc, C, d)
    # all-to-all: rows -> expert owners; received leading dim = source rank
    xrecv = jax.lax.all_to_all(xsend, axis, split_axis=0, concat_axis=0, tiled=True)
    xrecv = xrecv.reshape(P_, E_loc, C, d).transpose(1, 0, 2, 3).reshape(E_loc, P_ * C, d)

    if order is None:
        ye = _expert_partial(xrecv, None if w_g is None else w_g, w_u, w_d,
                             activation)
    else:
        xrecv, w_g, w_u, w_d = trajectory.apply_order(order, xrecv, w_g,
                                                      w_u, w_d)
        ye = _expert_partial(xrecv, w_g, w_u, w_d, activation)
        ye = trajectory.restore_order(order, ye)
    ye = ye.astype(x.dtype)

    ysend = ye.reshape(E_loc, P_, C, d).transpose(1, 0, 2, 3).reshape(P_ * E_loc, C, d)
    yrecv = jax.lax.all_to_all(ysend.reshape(P_, E_loc, C, d), axis,
                               split_axis=0, concat_axis=0, tiled=True)
    yrecv = yrecv.reshape(E, C, d)
    y = jnp.einsum("tec,ecd->td", combine.astype(jnp.float32),
                   yrecv.astype(jnp.float32))
    aux = pmean_all(gating.aux_load_balance_loss(routing, E), pm_axes)
    return y.reshape(B, S, d).astype(x.dtype), aux


def moe_ep(params, x, moe: MoEConfig, activation, *, axis="model",
           schedule=None, routing=None):
    mesh = meshctx.get_mesh()
    P_ = 1 if mesh is None or axis not in mesh.axis_names else mesh.shape[axis]
    if P_ == 1 or moe.num_experts % P_:
        from .fse_dp import moe_fse_dp
        return moe_fse_dp(params, x, moe, activation, axis=axis,
                          schedule=schedule, routing=routing)
    if routing is not None:
        raise ValueError("precomputed Routing is only supported on the "
                         "single-device path")
    batch = meshctx.batch_axes(mesh, axis)
    import numpy as _np
    bsz = int(_np.prod([mesh.shape[a] for a in batch])) if batch else 1
    if x.shape[0] % max(bsz, 1):
        batch = None
        bsz = 1
    B_grp = x.shape[0] // max(bsz, 1)
    # token layout: seq-shard S over the model axis when it divides
    # (the train/prefill layout); otherwise shard the batch dim over
    # (data axes x model) — decode shapes with S < P (HD-MoE's hybrid
    # regime); otherwise EP cannot lower, degrade to expert streaming.
    if x.shape[1] % P_ == 0:
        x_spec = P(batch, axis, None)
    elif B_grp % P_ == 0:
        x_spec = P((tuple(batch) if batch else ()) + (axis,), None, None)
    else:
        from .fse_dp import moe_fse_dp
        return moe_fse_dp(params, x, moe, activation, axis=axis,
                          schedule=schedule)
    w_g = params.get("w_gate")
    fn = functools.partial(_local_ep, moe=moe, activation=activation, axis=axis, P_=P_, pm_axes=tuple(mesh.axis_names), schedule=schedule)
    if w_g is None:
        def fn2(x, wr, wu, wd):
            return fn(x, wr, None, wu, wd)
        return shard_map(fn2, mesh=mesh,
                         in_specs=(x_spec, P(None, None), P(axis, None, None),
                                   P(axis, None, None)),
                         out_specs=(x_spec, P()))(
            x, params["router"]["w_router"], params["w_up"], params["w_down"])

    def fn3(x, wr, wg, wu, wd):
        return fn(x, wr, wg, wu, wd)
    return shard_map(fn3, mesh=mesh,
                     in_specs=(x_spec, P(None, None), P(axis, None, None),
                               P(axis, None, None), P(axis, None, None)),
                     out_specs=(x_spec, P()))(
        x, params["router"]["w_router"], w_g, params["w_up"], params["w_down"])


# ---------------------------------------------------------------------------
# TP — d_expert sharding, replicated tokens, all-reduce combine
# ---------------------------------------------------------------------------

def _local_tp(x, wr, w_g, w_u, w_d, *, moe, activation, axis, P_, pm_axes,
              schedule=None):
    """Pipeline: route (replicated tokens) -> schedule -> dispatch ->
    sliced FFN -> psum combine.  The dynamic trajectory spans all E
    experts (weights are d_expert-sliced, not expert-sharded)."""
    from repro.models.moe import dispatch_masks
    from . import trajectory
    B, S, d = x.shape
    x2d = x.reshape(B * S, d)
    T = x2d.shape[0]
    C = _capacity(T, moe)
    routing = _route(wr, x2d, moe)
    order = _local_trajectory(
        schedule, lambda: gating.expert_token_counts(routing))
    dispatch, combine = dispatch_masks(routing, T, moe.num_experts, C)
    xe = jnp.einsum("tec,td->ecd", dispatch.astype(x2d.dtype), x2d)
    if order is None:
        ye = _expert_partial(xe, w_g, w_u, w_d, activation)
    else:
        xe, w_g, w_u, w_d = trajectory.apply_order(order, xe, w_g, w_u, w_d)
        ye = trajectory.restore_order(
            order, _expert_partial(xe, w_g, w_u, w_d, activation))
    y = jnp.einsum("tec,ecd->td", combine.astype(jnp.float32), ye)
    y = jax.lax.psum(y, axis)
    aux = gating.aux_load_balance_loss(routing, moe.num_experts)
    aux = pmean_all(aux, pm_axes)
    return y.reshape(B, S, d).astype(x.dtype), aux


def moe_tp(params, x, moe: MoEConfig, activation, *, axis="model",
           schedule=None, routing=None):
    mesh = meshctx.get_mesh()
    P_ = 1 if mesh is None or axis not in mesh.axis_names else mesh.shape[axis]
    if P_ == 1:
        from .fse_dp import moe_fse_dp
        return moe_fse_dp(params, x, moe, activation, axis=axis,
                          schedule=schedule, routing=routing)
    if routing is not None:
        raise ValueError("precomputed Routing is only supported on the "
                         "single-device path")
    batch = meshctx.batch_axes(mesh, axis)
    import numpy as _np
    bsz = int(_np.prod([mesh.shape[a] for a in batch])) if batch else 1
    if x.shape[0] % max(bsz, 1):
        batch = None
    x_spec = P(batch, None, None)
    fn = functools.partial(_local_tp, moe=moe, activation=activation, axis=axis, P_=P_, pm_axes=tuple(mesh.axis_names), schedule=schedule)
    w_g = params.get("w_gate")
    if w_g is None:
        def fn2(x, wr, wu, wd):
            return fn(x, wr, None, wu, wd)
        return shard_map(fn2, mesh=mesh,
                         in_specs=(x_spec, P(None, None), P(None, None, axis),
                                   P(None, axis, None)),
                         out_specs=(x_spec, P()))(
            x, params["router"]["w_router"], params["w_up"], params["w_down"])

    def fn3(x, wr, wg, wu, wd):
        return fn(x, wr, wg, wu, wd)
    return shard_map(fn3, mesh=mesh,
                     in_specs=(x_spec, P(None, None), P(None, None, axis),
                               P(None, None, axis), P(None, axis, None)),
                     out_specs=(x_spec, P()))(
        x, params["router"]["w_router"], w_g, params["w_up"], params["w_down"])


def ep_moe_3d(params, x, moe, activation, *, axis="model"):
    """Deprecated shim: use ``repro.core.strategy.execute('ep', ...)``."""
    from .strategy import warn_deprecated_entry
    warn_deprecated_entry("ep_moe_3d", "ep")
    return moe_ep(params, x, moe, activation, axis=axis)


def tp_moe_3d(params, x, moe, activation, *, axis="model"):
    """Deprecated shim: use ``repro.core.strategy.execute('tp', ...)``."""
    from .strategy import warn_deprecated_entry
    warn_deprecated_entry("tp_moe_3d", "tp")
    return moe_tp(params, x, moe, activation, axis=axis)
