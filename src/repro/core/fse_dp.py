"""FSE-DP — Fully Sharded Expert Data-parallelism (the paper's §III–IV).

TPU-native realization of expert streaming:

* every device on the ``model`` mesh axis holds ``1/P`` of **every**
  expert's FFN weights, sliced along ``d_expert`` (exactly one copy of
  each expert per model group — the paper's "pooled buffer");
* tokens stay **stationary** (sequence-sharded over the same axis —
  handed over reduce-scatter style from attention, so no replication);
* expert slices **stream** around a logical ring via
  ``jax.lax.ppermute`` (point-to-point collective-permute — the D2D
  link analogue; *no all-to-all anywhere*);
* each per-device slice is further cut into ``micro_slices`` so the
  ring runs P·M finer steps; the scan carries the in-flight micro-slice
  and XLA's async collective-permute overlaps the transfer of step
  *s+1* with the grouped GEMM of step *s* — the paper's micro-slice
  flow (Fig. 4) in SPMD form;
* the partial-output sum over slices is order-invariant (elementwise
  activation commutes with the d_expert split), which is the paper's
  virtualization argument: trajectory timing/ordering is immaterial.

Each shard_map body is one pass of the shared route -> schedule ->
dispatch -> combine pipeline (``repro.core.trajectory``): under a
dynamic schedule the dispatched expert rows and arriving weight
micro-slices are reindexed into the gating-count-built paired-load
trajectory (and restored before the combine), so dynamic scheduling
reorders per-expert execution without changing a single bit of output.

Three execution modes, chosen statically from the token layout
(paper Fig. 3(a) vs 3(b)):

  stream — tokens seq-sharded, weight slices circulate  (train/prefill)
  index  — tokens replicated; each device takes a 1/P token slice and
           outputs are all-gathered (decode with enough tokens)
  slice  — tiny-token fallback: weights stay put, every device computes
           its d_expert slice for all tokens, partial outputs psum'd
           (the paper's own observation that token-side exchange wins
           when the token count is small)
"""
from __future__ import annotations

import functools
import inspect

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import MoEConfig
from repro.kernels import ops as kops
from repro.parallel import meshctx
from . import gating

try:  # jax>=0.6 exposes shard_map at top level
    _jax_shard_map = jax.shard_map  # type: ignore[attr-defined]
except Exception:  # pragma: no cover
    from jax.experimental.shard_map import shard_map as _jax_shard_map  # type: ignore

# replication-checker kwarg is check_rep (jax<=0.5) / check_vma (jax>=0.6)
_CHECK_KW = next((k for k in ("check_vma", "check_rep")
                  if k in inspect.signature(_jax_shard_map).parameters), None)


def shard_map(fn, *, mesh, in_specs, out_specs, **kw):
    """shard_map with the static replication checker off by default:
    ``pallas_call`` (the streamed-MoE kernel inside the body) has no
    replication rule, and jax 0.4.x's checker rewrite of an enclosing
    ``lax.scan`` mis-infers the aux-loss carry as non-replicated even on
    the pure-jnp path (the seed dry-run failure).  All replicated outputs
    here (aux, index-mode y) are explicitly pmean/psum'd, so the check is
    redundant.  Callers can re-enable it via the keyword."""
    if _CHECK_KW and _CHECK_KW not in kw:
        kw[_CHECK_KW] = False
    return _jax_shard_map(fn, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, **kw)


def pmean_all(x, axes):
    """pmean over ``axes`` regardless of which of them x varies on
    (pvary the missing ones first — vma-safe)."""
    try:
        vma = jax.typeof(x).vma
        missing = tuple(a for a in axes if a not in vma)
        if missing:
            x = jax.lax.pvary(x, missing)
    except Exception:
        pass
    return jax.lax.pmean(x, axes)


# ---------------------------------------------------------------------------
# local grouped-GEMM over one micro-slice
# ---------------------------------------------------------------------------

def _expert_partial(xe, w_g, w_u, w_d, activation, kopts=None):
    """xe: (E,C,d); w_g/w_u: (E,d,m); w_d: (E,m,d) -> partial y (E,C,d) fp32.

    Dispatches through ``kernels.ops.streamed_moe``: the Pallas micro-slice
    kernel when kernels are enabled, the jnp oracle under
    ``use_kernels(False)`` / REPRO_NO_PALLAS.  ``kopts`` is a tuple of
    (name, value) tile kwargs from an autotune :class:`Plan`; ``None``
    consults the ambient-level tile planner for this call's shape."""
    if kopts is None:
        return kops.streamed_moe_autotuned(xe, w_g, w_u, w_d, activation)
    return kops.streamed_moe(xe, w_g, w_u, w_d, activation, **dict(kopts))


def _ring_stream(xe, w_g, w_u, w_d, activation, axis, P_, micro_slices,
                 kopts=None, order=None):
    """Accumulate full expert outputs for local dispatched tokens ``xe``
    while streaming weight micro-slices around the ``axis`` ring.

    w_*: local shard (E, d, de_loc) / (E, de_loc, d).

    ``order`` is an optional expert-trajectory permutation (dynamic
    schedule, ``core.trajectory``): the dispatched rows and each
    arriving weight micro-slice are reindexed into trajectory order so
    the grouped-GEMM grid walks hot/cold experts interleaved, and the
    accumulated outputs are restored to canonical order afterwards —
    per-expert compute is independent, so values are bit-identical to
    the static path.  The circulated slices stay in canonical order
    (each rank applies its *own* trajectory locally).
    """
    from . import trajectory
    E, C, d = xe.shape
    de_loc = w_g.shape[-1] if w_g is not None else w_u.shape[-1]
    M = max(1, min(micro_slices, de_loc))
    while de_loc % M:
        M -= 1  # largest feasible micro-slice count <= requested
    mic = de_loc // M

    if order is not None:
        (xe,) = trajectory.apply_order(order, xe)
    ring = [(i, (i + 1) % P_) for i in range(P_)]
    # zeros_like inherits xe's varying-manual-axes so the scan carry typechecks
    acc = jnp.zeros_like(xe, jnp.float32)

    for m in range(M):
        sl = slice(m * mic, (m + 1) * mic)
        cur = (
            w_g[..., sl] if w_g is not None else None,
            w_u[..., sl],
            w_d[:, sl, :],
        )

        def step(carry, _):
            acc, (cg, cu, cd) = carry
            # Rule 1: forward the micro-slice being computed — the permute
            # is issued first so XLA's async collective-permute overlaps
            # it with the grouped GEMM below (micro-slice flow, Fig. 4b).
            ng = jax.lax.ppermute(cg, axis, ring) if cg is not None else None
            nu = jax.lax.ppermute(cu, axis, ring)
            nd = jax.lax.ppermute(cd, axis, ring)
            if order is None:
                kg, ku, kd = cg, cu, cd
            else:
                kg, ku, kd = trajectory.apply_order(order, cg, cu, cd)
            acc = acc + _expert_partial(xe, kg, ku, kd, activation, kopts)
            return (acc, (ng, nu, nd)), None

        (acc, _), _ = jax.lax.scan(step, (acc, cur), None, length=P_)
    if order is not None:
        acc = trajectory.restore_order(order, acc)
    return acc


# ---------------------------------------------------------------------------
# shard_map bodies — each one is the route -> schedule -> dispatch ->
# combine pipeline (repro.core.trajectory) over its SPMD dataflow
# ---------------------------------------------------------------------------

def _route(wr, x2d, moe):
    """Pipeline *route* stage: Routing for the local token rows."""
    return gating.route({"w_router": wr}, x2d, top_k=moe.top_k)


def _schedule_order(schedule, routing):
    """Pipeline *schedule* stage: the expert-trajectory permutation, or
    ``None`` for a static schedule (identity trajectory, untouched fast
    path).  A dynamic schedule without a host-built order derives it
    in-graph from this rank's own routing counts."""
    from . import trajectory
    return trajectory.resolve_order(
        schedule, lambda: gating.expert_token_counts(routing))


def _dispatch(x2d, routing, moe, order=None):
    """Pipeline *dispatch* stage: (xe, combiner) — combiner(ye fp32
    (E,C,d)) -> y (T,d) fp32.  ``order`` reindexes the dispatched rows
    into trajectory order; the combiner always consumes canonical-order
    outputs (callers restore before combining)."""
    from repro.models.moe import (capacity_of, dispatch_masks, dispatch_tables,
                                  gather_dispatch, scatter_combine,
                                  sorted_dispatch_enabled)
    from . import trajectory
    T = x2d.shape[0]
    C = capacity_of(T, moe)
    if sorted_dispatch_enabled():
        idx, wts = dispatch_tables(routing, T, moe.num_experts, C)
        xe = gather_dispatch(x2d, idx)
        if order is not None:
            (xe,) = trajectory.apply_order(order, xe)
        return xe, lambda ye: scatter_combine(ye, idx, wts, T)
    dispatch, combine = dispatch_masks(routing, T, moe.num_experts, C)
    xe = jnp.einsum("tec,td->ecd", dispatch.astype(x2d.dtype), x2d)
    if order is not None:
        (xe,) = trajectory.apply_order(order, xe)
    comb = lambda ye: jnp.einsum("tec,ecd->td", combine.astype(jnp.float32), ye)
    return xe, comb


def _local_moe_stream(x, wr, w_g, w_u, w_d, *, moe, activation, axis, P_,
                      pm_axes, micro_slices=None, kopts=None, schedule=None):
    """x: (B_loc, S_loc, d) — tokens stationary, weights stream."""
    B, S, d = x.shape
    x2d = x.reshape(B * S, d)
    routing = _route(wr, x2d, moe)
    order = _schedule_order(schedule, routing)
    # the ring applies the trajectory itself (per arriving micro-slice)
    xe, combine = _dispatch(x2d, routing, moe)
    ye = _ring_stream(xe, w_g, w_u, w_d, activation, axis, P_,
                      micro_slices or moe.micro_slices, kopts, order)
    y = combine(ye.reshape(moe.num_experts, -1, d))
    aux = gating.aux_load_balance_loss(routing, moe.num_experts)
    aux = pmean_all(aux, pm_axes)
    return y.reshape(B, S, d).astype(x.dtype), aux


def _local_moe_index(x, wr, w_g, w_u, w_d, *, moe, activation, axis, P_,
                     pm_axes, micro_slices=None, kopts=None, schedule=None):
    """x replicated over ``axis``: each rank handles a 1/P token slice,
    streams the weights, then all-gathers the outputs."""
    B, S, d = x.shape
    x2d = x.reshape(B * S, d)
    T = x2d.shape[0]
    T_loc = T // P_
    r = jax.lax.axis_index(axis)
    x_loc = jax.lax.dynamic_slice_in_dim(x2d, r * T_loc, T_loc, 0)
    routing = _route(wr, x_loc, moe)
    order = _schedule_order(schedule, routing)
    xe, combine = _dispatch(x_loc, routing, moe)
    ye = _ring_stream(xe, w_g, w_u, w_d, activation, axis, P_,
                      micro_slices or moe.micro_slices, kopts, order)
    y_loc = combine(ye.reshape(moe.num_experts, -1, d))
    # scatter-into-zeros + psum == all-gather, but provably replicated
    # under shard_map's varying-axes checker
    y = jnp.zeros((T, d), jnp.float32)
    y = jax.lax.dynamic_update_slice_in_dim(y, y_loc, r * T_loc, 0)
    y = jax.lax.psum(y, axis).astype(x.dtype)
    aux = pmean_all(gating.aux_load_balance_loss(routing, moe.num_experts), pm_axes)
    return y.reshape(B, S, d), aux


def _local_moe_slice(x, wr, w_g, w_u, w_d, *, moe, activation, axis, P_,
                     pm_axes, micro_slices=None, kopts=None, schedule=None):
    """Tiny-token fallback (paper Fig. 3(b) regime): weights stationary,
    every rank computes its d_expert slice for all tokens, psum combine."""
    from . import trajectory
    B, S, d = x.shape
    x2d = x.reshape(B * S, d)
    routing = _route(wr, x2d, moe)
    order = _schedule_order(schedule, routing)
    xe, combine = _dispatch(x2d, routing, moe, order)
    if order is not None:
        w_g, w_u, w_d = trajectory.apply_order(order, w_g, w_u, w_d)
    ye = _expert_partial(xe, w_g, w_u, w_d, activation, kopts)
    if order is not None:
        ye = trajectory.restore_order(order, ye)
    y = combine(ye)
    y = jax.lax.psum(y, axis)
    aux = gating.aux_load_balance_loss(routing, moe.num_experts)
    aux = pmean_all(aux, pm_axes)
    return y.reshape(B, S, d).astype(x.dtype), aux


# ---------------------------------------------------------------------------
# public entry
# ---------------------------------------------------------------------------

# deprecated zero-knowledge mode heuristic — kept as the historical export;
# survives as the fallback of the cost-model autotuner (autotune.fallback_plan)
from .autotune import pick_mode  # noqa: E402


def moe_fse_dp(params, x, moe: MoEConfig, activation, *, axis="model",
               plan=None, schedule=None, routing=None):
    """x: (B, S, d) global. Returns (y, aux). Falls back to the
    single-device capacity path when no model-parallel mesh is active.

    Execution mode, ring micro-slice count, and kernel tile shapes come
    from a ``core.autotune.Plan``: pass one explicitly (forced mode), or
    leave ``plan=None`` to let the cost-model planner score
    {stream, index, slice} x micro_slices x tiles for this shape at the
    ambient autotune level.  Level 'off' applies the legacy static
    heuristic — evaluated on the per-model-group batch (B/data-axis),
    which the shard_map bodies actually see, not the global B the old
    ``pick_mode`` call used; for shapes where those differ the per-group
    choice is the one whose divisibility requirements actually hold.

    ``schedule`` (a ``core.trajectory.Schedule``) selects the expert
    trajectory: ``None``/static is the untouched fast path; dynamic
    reindexes per-expert compute into paired-load order (bit-identical
    outputs, reordered execution).  A schedule that carries a load-aware
    plan supplies it when no explicit ``plan`` is given (an explicit
    plan always wins).  ``routing`` pre-computes the route stage —
    only the single-device fallback accepts it (the distributed bodies
    route their local token rows inside ``shard_map``)."""
    if schedule is not None and schedule.plan is not None and plan is None:
        plan = schedule.plan
    mesh = meshctx.get_mesh()
    P_ = 1 if mesh is None or axis not in mesh.axis_names else mesh.shape[axis]
    if P_ == 1:
        from repro.models.moe import moe_capacity
        shape = x.shape
        x2d = x.reshape(-1, shape[-1])
        if routing is None:
            routing = gating.route(params["router"], x2d, top_k=moe.top_k)
        y = moe_capacity(params, x2d, routing, moe, activation,
                         schedule=schedule)
        return y.reshape(shape), gating.aux_load_balance_loss(routing, moe.num_experts)
    if routing is not None:
        raise ValueError("precomputed Routing is only supported on the "
                         "single-device path; distributed bodies route "
                         "their local token rows inside shard_map")

    B, S, d = x.shape
    batch = meshctx.batch_axes(mesh, axis)
    import numpy as _np
    bsz = int(_np.prod([mesh.shape[a] for a in batch])) if batch else 1
    b_ax = batch if (batch and B % bsz == 0) else None
    B_grp = B // bsz if b_ax else B         # tokens within one model group

    if plan is None:
        from . import autotune
        from repro.kernels import quant
        plan = autotune.plan_moe(B_grp, S, d, moe, activation, P_,
                                 dtype_bytes=jnp.dtype(x.dtype).itemsize,
                                 weight_bytes=quant.weight_bytes())
    mode = plan.mode
    kopts = tuple(sorted(plan.kernel_opts().items()))
    body = {"stream": _local_moe_stream,
            "index": _local_moe_index,
            "slice": _local_moe_slice}[mode]

    x_spec = P(b_ax, axis if mode == "stream" else None, None)
    specs_in = (
        x_spec,
        P(None, None),            # router
        P(None, None, axis),      # w_gate (E,d,de)
        P(None, None, axis),      # w_up
        P(None, axis, None),      # w_down (E,de,d)
    )
    specs_out = (x_spec, P())

    fn = functools.partial(body, moe=moe, activation=activation, axis=axis,
                           P_=P_, pm_axes=tuple(mesh.axis_names),
                           micro_slices=plan.micro_slices, kopts=kopts,
                           schedule=schedule)
    w_g = params.get("w_gate")
    if w_g is None:
        # relu2/gelu experts: no gate projection; reuse w_up spec slot
        def fn2(x, wr, wu, wd):
            return fn(x, wr, None, wu, wd)
        return shard_map(fn2, mesh=mesh,
                         in_specs=(specs_in[0], specs_in[1], specs_in[3], specs_in[4]),
                         out_specs=specs_out)(
            x, params["router"]["w_router"], params["w_up"], params["w_down"])

    def fn3(x, wr, wg, wu, wd):
        return fn(x, wr, wg, wu, wd)

    return shard_map(fn3, mesh=mesh, in_specs=specs_in, out_specs=specs_out)(
        x, params["router"]["w_router"], w_g, params["w_up"], params["w_down"])


def fse_dp_moe_3d(params, x, moe, activation, *, axis="model", plan=None):
    """Deprecated shim: use ``repro.core.strategy.execute('fse_dp', ...)``."""
    from .strategy import warn_deprecated_entry
    warn_deprecated_entry("fse_dp_moe_3d", "fse_dp")
    return moe_fse_dp(params, x, moe, activation, axis=axis, plan=plan)
