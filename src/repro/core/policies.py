"""Scheduling policies from the paper (§IV-A, §V).

These are host-side policies consumed by the chiplet simulator
(``repro.sim``) and the serving engine (``repro.serving``): the
paired-load expert ordering and the token-buffering QoS mechanism
(Algorithm 2).  They operate on plain ints / numpy arrays so the same
code drives both the cycle-level simulation and the JAX engine.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Sequence

import numpy as np


# ---------------------------------------------------------------------------
# Paired-load policy (§IV-A, Fig. 5)
# ---------------------------------------------------------------------------

def paired_load_order(token_counts: Sequence[int]) -> List[int]:
    """Sort experts by activation count and pair opposite ends.

    Returns the expert *load order* [hot1, cold1, hot2, cold2, ...] so
    compute-bound (hot) and DDR-bound (cold) expert flows interleave.
    Experts with zero tokens are appended last (they are candidates for
    token buffering / skipping, not loading).
    """
    counts = np.asarray(token_counts)
    active = [int(e) for e in np.argsort(-counts, kind="stable") if counts[e] > 0]
    idle = [int(e) for e in np.argsort(-counts, kind="stable") if counts[e] == 0]
    order: List[int] = []
    lo, hi = 0, len(active) - 1
    while lo <= hi:
        order.append(active[lo])          # hot end
        if hi != lo:
            order.append(active[hi])      # cold end
        lo += 1
        hi -= 1
    return order + idle


def expert_pairs(token_counts: Sequence[int]) -> List[tuple]:
    """(hot, cold) pairs per the paired-load policy; odd expert out pairs
    with ``None``."""
    order = paired_load_order(token_counts)
    counts = np.asarray(token_counts)
    order = [e for e in order if counts[e] > 0]
    pairs = []
    for i in range(0, len(order), 2):
        pairs.append((order[i], order[i + 1] if i + 1 < len(order) else None))
    return pairs


# ---------------------------------------------------------------------------
# Token buffering (Algorithm 2)
# ---------------------------------------------------------------------------

@dataclass
class QoSState:
    """Per-request token-buffering bookkeeping (paper Algorithm 2)."""
    timer: int = 0          # T_QoS(r)
    fw_count: int = 0       # C_fw(r)
    deferrals: int = 0      # total buffering events (stats)


@dataclass
class TokenBufferPolicy:
    """Algorithm 2: defer a request at an MoE-layer boundary when it
    activates a cold expert and has QoS slack.

    ``n_threshold`` forward passes earn one buffering credit;
    ``theta_min`` is the cold-expert token threshold.  ``slack``
    (e.g. 0.10/0.20/0.30 in the paper's end-to-end runs) sets
    n_threshold = ceil(1/slack) so a request can be deferred for at
    most ~``slack`` of its forward passes.
    """
    theta_min: int = 4
    n_threshold: int = 10
    states: Dict[str, QoSState] = field(default_factory=dict)

    @classmethod
    def from_slack(cls, slack: float, theta_min: int = 4) -> "TokenBufferPolicy":
        if slack <= 0:
            return cls(theta_min=theta_min, n_threshold=1 << 30)
        return cls(theta_min=theta_min, n_threshold=max(1, int(np.ceil(1.0 / slack))))

    def state(self, rid: str) -> QoSState:
        return self.states.setdefault(rid, QoSState())

    def on_forward_pass(self, rid: str) -> None:
        """Call once per completed forward pass of request ``rid``
        (Algorithm 2 lines 2–5)."""
        st = self.state(rid)
        st.fw_count += 1
        if st.fw_count >= self.n_threshold:
            st.timer += 1
            st.fw_count = 0

    def should_defer(self, rid: str, activated_experts: Sequence[int],
                     expert_token_counts: Sequence[int]) -> bool:
        """Algorithm 2 lines 6–9: defer iff some activated expert is cold
        (n_e < theta_min) and T_QoS > 0. Decrements the timer on defer."""
        st = self.state(rid)
        if st.timer <= 0:
            return False
        counts = np.asarray(expert_token_counts)
        cold = any(counts[e] < self.theta_min for e in activated_experts)
        if cold:
            st.timer -= 1
            st.deferrals += 1
            return True
        return False

    def drop(self, rid: str) -> None:
        self.states.pop(rid, None)
