"""DeepSeek-MoE 16B — paper Table-I workload model (64 experts, 6+2 shared).

[arXiv:2401.06066 / paper Table I; hf]
"""
from .base import ModelConfig, MoEConfig, register

CONFIG = register(ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab_size=102400,
    activation="swiglu",
    norm="rmsnorm",
    moe=MoEConfig(num_experts=64, top_k=6, d_expert=1408,
                  num_shared_experts=2, impl="fse_dp"),
    moe_every=1,
    source="paper Table I / arXiv:2401.06066",
    verified="hf",
))


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="deepseek-moe-16b-reduced", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=4, head_dim=16, d_ff=64, vocab_size=128,
        moe=MoEConfig(num_experts=8, top_k=2, d_expert=64,
                      num_shared_experts=1, impl="dense"))
