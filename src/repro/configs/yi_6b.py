"""Yi-6B — llama-architecture dense GQA transformer. [arXiv:2403.04652; hf]"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="yi-6b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=11008,
    vocab_size=64000,
    activation="swiglu",
    norm="rmsnorm",
    rope_theta=5000000.0,
    source="arXiv:2403.04652",
    verified="hf",
))


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="yi-6b-reduced", num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=2, head_dim=16, d_ff=160, vocab_size=128)
