"""Granite-3.0 1B-A400M — fine-grained MoE, 32 experts top-8, d_expert=512.

[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]
"""
from .base import ModelConfig, MoEConfig, register

CONFIG = register(ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab_size=49155,
    activation="swiglu",
    norm="rmsnorm",
    moe=MoEConfig(num_experts=32, top_k=8, d_expert=512, impl="fse_dp"),
    moe_every=1,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
    verified="hf",
))


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="granite-moe-1b-a400m-reduced", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, head_dim=16, d_ff=64, vocab_size=128,
        moe=MoEConfig(num_experts=8, top_k=2, d_expert=64, impl="dense"))
