"""Phi-3.5-MoE 42B-A6.6B — 16 experts top-2. Also a paper Table-I model.

[hf:microsoft/Phi-3.5-MoE-instruct; hf]
"""
from .base import ModelConfig, MoEConfig, register

CONFIG = register(ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=6400,
    vocab_size=32064,
    activation="swiglu",
    norm="layernorm",
    moe=MoEConfig(num_experts=16, top_k=2, d_expert=6400, impl="fse_dp"),
    moe_every=1,
    source="hf:microsoft/Phi-3.5-MoE-instruct",
    verified="hf",
))


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="phi3.5-moe-42b-a6.6b-reduced", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=128,
        moe=MoEConfig(num_experts=4, top_k=2, d_expert=128, impl="dense"))
