"""Yuan2.0-M32 — paper Table-I workload model (32 experts top-2).

[arXiv:2405.17976 / paper Table I; unverified]
"""
from .base import ModelConfig, MoEConfig, register

CONFIG = register(ModelConfig(
    name="yuan2-m32",
    family="moe",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    head_dim=128,
    d_ff=4096,
    vocab_size=135040,
    activation="swiglu",
    norm="rmsnorm",
    moe=MoEConfig(num_experts=32, top_k=2, d_expert=4096, impl="fse_dp"),
    moe_every=1,
    source="paper Table I / arXiv:2405.17976",
    verified="unverified",
))


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="yuan2-m32-reduced", num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=128,
        moe=MoEConfig(num_experts=8, top_k=2, d_expert=128, impl="dense"))
