"""Qwen3-30B-A3B — paper Table-I workload model (128 experts top-8).

[arXiv:2505.09388 / paper Table I; hf]
"""
from .base import ModelConfig, MoEConfig, register

CONFIG = register(ModelConfig(
    name="qwen3-30b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=768,
    vocab_size=151936,
    activation="swiglu",
    norm="rmsnorm",
    moe=MoEConfig(num_experts=128, top_k=8, d_expert=768, impl="fse_dp"),
    moe_every=1,
    source="paper Table I / arXiv:2505.09388",
    verified="hf",
))


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="qwen3-30b-a3b-reduced", num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=2, head_dim=16, d_ff=64, vocab_size=128,
        moe=MoEConfig(num_experts=8, top_k=4, d_expert=64, impl="dense"))
