from .base import ModelConfig, MoEConfig, SSMConfig, FrontendConfig, get_config, list_configs, register
from .shapes import SHAPES, SHAPE_ORDER, ShapeSpec, applicable, cells

ASSIGNED_ARCHS = [
    "nemotron-4-15b", "yi-6b", "stablelm-1.6b", "nemotron-4-340b",
    "jamba-v0.1-52b", "whisper-base", "granite-moe-1b-a400m",
    "phi3.5-moe-42b-a6.6b", "internvl2-2b", "mamba2-370m",
]

PAPER_MODELS = ["phi3.5-moe-42b-a6.6b", "yuan2-m32", "deepseek-moe-16b", "qwen3-30b-a3b"]


def reduced_config(name: str):
    """Return the reduced (smoke-test) variant of a registered arch."""
    import importlib
    from .base import _ARCH_MODULES
    for m in _ARCH_MODULES:
        mod = importlib.import_module(f"repro.configs.{m}")
        if mod.CONFIG.name == name:
            return mod.reduced()
    raise KeyError(name)
