"""Nemotron-4 340B — dense GQA transformer, squared-ReLU FFN.

[arXiv:2402.16819; unverified]
"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="nemotron-4-340b",
    family="dense",
    num_layers=96,
    d_model=18432,
    num_heads=96,
    num_kv_heads=8,
    head_dim=192,
    d_ff=73728,
    vocab_size=256000,
    activation="relu2",
    norm="layernorm",
    rope_theta=10000.0,
    source="arXiv:2402.16819",
    verified="unverified",
))


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="nemotron-4-340b-reduced", num_layers=3, d_model=96, num_heads=6,
        num_kv_heads=2, head_dim=16, d_ff=384, vocab_size=128)
