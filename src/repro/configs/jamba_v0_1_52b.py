"""Jamba-v0.1 52B — hybrid Mamba+attention (1:7 interleave) with MoE.

MoE 16 experts top-2 on every other layer. [arXiv:2403.19887; hf]
Mamba-1 blocks are realized with the repo's unified SSD block
(d_state=16) — see DESIGN.md §2 assumption log.
"""
from .base import ModelConfig, MoEConfig, SSMConfig, register

CONFIG = register(ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=65536,
    activation="swiglu",
    norm="rmsnorm",
    attn_every=8,              # 1 attn per 8 layers (1:7)
    moe=MoEConfig(num_experts=16, top_k=2, d_expert=14336, impl="fse_dp"),
    moe_every=2,               # MoE every other layer
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=64),
    source="arXiv:2403.19887",
    verified="hf",
))


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="jamba-v0.1-52b-reduced", num_layers=8, d_model=64, num_heads=4,
        num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=128,
        moe=MoEConfig(num_experts=4, top_k=2, d_expert=128, impl="dense"),
        ssm=SSMConfig(d_state=8, d_conv=4, expand=2, head_dim=16, chunk_size=32))
