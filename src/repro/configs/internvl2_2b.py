"""InternVL2-2B — InternViT (stub) + InternLM2-1.8B backbone.

Vision frontend is a stub: ``input_specs`` supplies precomputed patch
embeddings prepended as prefix tokens. [arXiv:2404.16821; hf]
"""
from .base import ModelConfig, FrontendConfig, register

CONFIG = register(ModelConfig(
    name="internvl2-2b",
    family="vlm",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=92553,
    activation="swiglu",
    norm="rmsnorm",
    rope_theta=1000000.0,
    frontend=FrontendConfig(kind="vision", num_prefix_tokens=256),
    source="arXiv:2404.16821",
    verified="hf",
))


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="internvl2-2b-reduced", num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=128,
        frontend=FrontendConfig(kind="vision", num_prefix_tokens=8))
