"""Nemotron-4 15B — dense GQA transformer with squared-ReLU FFN.

[arXiv:2402.16819; unverified]
"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="nemotron-4-15b",
    family="dense",
    num_layers=32,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=256000,
    activation="relu2",
    norm="layernorm",
    rope_theta=10000.0,
    source="arXiv:2402.16819",
    verified="unverified",
))


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="nemotron-4-15b-reduced", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=128)
