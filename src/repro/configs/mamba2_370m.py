"""Mamba2-370M — attention-free SSD (state-space duality) LM.

[arXiv:2405.21060; unverified]
"""
from .base import ModelConfig, SSMConfig, register

CONFIG = register(ModelConfig(
    name="mamba2-370m",
    family="ssm",
    num_layers=48,
    d_model=1024,
    num_heads=0,
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=50280,
    norm="rmsnorm",
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64),
    tie_embeddings=True,
    source="arXiv:2405.21060",
    verified="unverified",
))


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="mamba2-370m-reduced", num_layers=2, d_model=64, vocab_size=128,
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16, chunk_size=32))
