"""Configuration dataclasses for the repro framework.

Every architecture in ``repro.configs`` is described by a frozen
:class:`ModelConfig`.  Configs are pure data — building parameters or
choosing shardings happens in ``repro.models`` / ``repro.parallel``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Sub-configs
# ---------------------------------------------------------------------------


def moe_capacity_rows(tokens: int, top_k: int, num_experts: int,
                      capacity_factor: float) -> int:
    """Per-expert capacity C = max(1, ceil(tokens*top_k/E*cf)).

    The single source of truth for every capacity computation: the
    executed dispatch (``models.moe`` / ``core.baselines``), the cost
    model (``core.autotune``), and the mode simulator (``sim.modes``)
    all delegate here so they can never disagree on C.
    """
    import math
    return max(1, math.ceil(tokens * top_k / num_experts * capacity_factor))


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-Experts FFN block configuration."""

    num_experts: int
    top_k: int
    d_expert: int                      # per-expert FFN hidden dim
    num_shared_experts: int = 0        # DeepSeek-style always-on experts
    capacity_factor: float = 1.25      # EP baseline dispatch capacity
    router_jitter: float = 0.0
    aux_loss_coef: float = 0.01
    # FSE-DP knobs (paper §IV)
    micro_slices: int = 4              # micro-slices per per-device slice
    impl: str = "dense"                # default strategy name when no
                                       # ExecutionSpec is given (a
                                       # repro.core.strategy registry key)

    def __post_init__(self):
        assert self.top_k <= self.num_experts

    def capacity_rows(self, tokens: int) -> int:
        return moe_capacity_rows(tokens, self.top_k, self.num_experts,
                                 self.capacity_factor)


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 / SSD block configuration."""

    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64                 # SSD head dim (P)
    n_groups: int = 1
    chunk_size: int = 256              # SSD chunk length
    dt_min: float = 0.001
    dt_max: float = 0.1


@dataclass(frozen=True)
class FrontendConfig:
    """Modality frontend stub (audio frames / vision patches).

    The backbone consumes *precomputed* embeddings supplied by
    ``input_specs`` — per the assignment the frontend itself is a stub.
    """

    kind: str                          # "audio" | "vision"
    num_prefix_tokens: int = 256       # vision: patch tokens prepended
    frame_dim: int = 0                 # audio: dim of precomputed frames (=d_model)


# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                        # dense | moe | hybrid | ssm | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int                          # dense-FFN hidden dim (0 for pure SSM)
    vocab_size: int
    head_dim: int = 0                  # 0 -> d_model // num_heads
    activation: str = "swiglu"         # swiglu | relu2 | gelu
    norm: str = "rmsnorm"              # rmsnorm | layernorm
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    moe_every: int = 1                 # MoE FFN every k-th layer (others dense)
    ssm: Optional[SSMConfig] = None
    attn_every: int = 1                # hybrid: attention every k-th layer (others SSM)
    encoder_layers: int = 0            # enc-dec (whisper): encoder depth
    frontend: Optional[FrontendConfig] = None
    max_seq_len: int = 524_288
    dtype: str = "bfloat16"
    # provenance
    source: str = ""
    verified: str = "unverified"

    # ---- derived -----------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.num_heads if self.num_heads else 0

    @property
    def is_encoder_decoder(self) -> bool:
        return self.encoder_layers > 0

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def has_moe(self) -> bool:
        return self.moe is not None

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic decode: SSM/hybrid families only (per assignment)."""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decode(self) -> bool:
        """All assigned archs decode (enc-dec decodes with its decoder)."""
        return True

    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-decoder-layer mixer kind: 'attn' or 'ssm'."""
        kinds = []
        for i in range(self.num_layers):
            if self.family == "ssm":
                kinds.append("ssm")
            elif self.family == "hybrid":
                # paper-listed 1:7 attn:ssm interleave — one attn layer per
                # attn_every block, placed mid-block like Jamba (index 4 of 8;
                # we use the last slot of each period for scan regularity).
                kinds.append("attn" if (i % self.attn_every) == self.attn_every - 1 else "ssm")
            else:
                kinds.append("attn")
        return tuple(kinds)

    def ffn_kinds(self) -> Tuple[str, ...]:
        kinds = []
        for i in range(self.num_layers):
            if self.moe is not None and (i % self.moe_every) == self.moe_every - 1:
                kinds.append("moe")
            elif self.d_ff > 0:
                kinds.append("dense")
            else:
                kinds.append("none")   # pure SSM blocks carry their own mixing
        return tuple(kinds)

    # ---- parameter counting (used by roofline + config tests) --------
    def param_count(self) -> int:
        d, hd = self.d_model, self.resolved_head_dim
        n_q, n_kv = self.num_heads, self.num_kv_heads
        embed = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        total = embed
        attn = d * (n_q * hd) + 2 * d * (n_kv * hd) + (n_q * hd) * d
        dense_ffn = (3 if self.activation == "swiglu" else 2) * d * self.d_ff
        moe_ffn = 0
        if self.moe is not None:
            e, de = self.moe.num_experts, self.moe.d_expert
            per_e = (3 if self.activation == "swiglu" else 2) * d * de
            moe_ffn = e * per_e + d * e  # + router
            moe_ffn += self.moe.num_shared_experts * per_e
        ssm_p = 0
        if self.ssm is not None:
            di = self.ssm.expand * d
            nh = di // self.ssm.head_dim
            # in_proj (z,x,B,C,dt) + conv + out_proj + A,D
            ssm_p = d * (2 * di + 2 * self.ssm.n_groups * self.ssm.d_state + nh) \
                + self.ssm.d_conv * (di + 2 * self.ssm.n_groups * self.ssm.d_state) \
                + di * d + 2 * nh
        for i, (mix, ffn) in enumerate(zip(self.layer_kinds(), self.ffn_kinds())):
            total += attn if mix == "attn" else ssm_p
            if ffn == "dense":
                total += dense_ffn
            elif ffn == "moe":
                total += moe_ffn
            total += 2 * d  # norms
        if self.encoder_layers:
            # encoder self-attn+ffn, decoder cross-attn already excluded above;
            # add encoder stack + decoder cross-attention
            total += self.encoder_layers * (attn + dense_ffn + 2 * d)
            total += self.num_layers * (attn + d)  # cross-attn + norm
        return int(total)

    def active_param_count(self) -> int:
        """Activated params per token (MoE top-k instead of all experts)."""
        if self.moe is None:
            return self.param_count()
        full = self.param_count()
        e, k = self.moe.num_experts, self.moe.top_k
        per_e = (3 if self.activation == "swiglu" else 2) * self.d_model * self.moe.d_expert
        n_moe_layers = sum(1 for f in self.ffn_kinds() if f == "moe")
        return int(full - n_moe_layers * (e - k) * per_e)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    _load_all()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> list:
    _load_all()
    return sorted(_REGISTRY)


_ARCH_MODULES = [
    "nemotron_4_15b", "yi_6b", "stablelm_1_6b", "nemotron_4_340b",
    "jamba_v0_1_52b", "whisper_base", "granite_moe_1b", "phi3_5_moe",
    "internvl2_2b", "mamba2_370m",
    # paper Table-I workload models (simulator + extra configs)
    "deepseek_moe_16b", "qwen3_30b_a3b", "yuan2_m32",
]

_loaded = False


def _load_all():
    global _loaded
    if _loaded:
        return
    import importlib
    for m in _ARCH_MODULES:
        importlib.import_module(f"repro.configs.{m}")
    _loaded = True
