"""Whisper-base — encoder-decoder audio transformer (backbone only).

The conv frontend is a stub: ``input_specs`` supplies precomputed frame
embeddings of shape (batch, frames, d_model). [arXiv:2212.04356; unverified]
"""
from .base import ModelConfig, FrontendConfig, register

CONFIG = register(ModelConfig(
    name="whisper-base",
    family="audio",
    num_layers=6,              # decoder layers
    encoder_layers=6,
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    head_dim=64,
    d_ff=2048,
    vocab_size=51865,
    activation="gelu",
    norm="layernorm",
    frontend=FrontendConfig(kind="audio", frame_dim=512),
    source="arXiv:2212.04356",
    verified="unverified",
))


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="whisper-base-reduced", num_layers=2, encoder_layers=2,
        d_model=64, num_heads=4, num_kv_heads=4, head_dim=16, d_ff=128,
        vocab_size=128, frontend=FrontendConfig(kind="audio", frame_dim=64))
