"""Assigned input-shape cells and applicability rules.

Four shapes per LM-family arch (40 cells total):

  train_4k     seq=4096   global_batch=256   -> train_step
  prefill_32k  seq=32768  global_batch=32    -> prefill_step (inference)
  decode_32k   seq=32768  global_batch=128   -> serve_step (1 new token, KV=seq)
  long_500k    seq=524288 global_batch=1     -> serve_step; sub-quadratic only
"""
from __future__ import annotations

from dataclasses import dataclass
from .base import ModelConfig


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k":    ShapeSpec("train_4k",    4_096,   256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  ShapeSpec("decode_32k",  32_768,  128, "decode"),
    "long_500k":   ShapeSpec("long_500k",   524_288, 1,   "decode"),
}

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def applicable(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """(runs?, reason-if-skipped) for an (arch, shape) cell."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, ("long_500k requires sub-quadratic attention; "
                       f"{cfg.name} is pure full-attention (skip per assignment)")
    if shape.kind == "decode" and not cfg.has_decode:
        return False, f"{cfg.name} is encoder-only; no decode step"
    return True, ""


def cells(cfg: ModelConfig):
    """All four cells with applicability annotation."""
    out = []
    for sname in SHAPE_ORDER:
        s = SHAPES[sname]
        ok, why = applicable(cfg, s)
        out.append((s, ok, why))
    return out
