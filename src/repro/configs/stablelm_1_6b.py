"""StableLM-2 1.6B — dense transformer, kv=32 (MHA-equivalent GQA).

[hf:stabilityai/stablelm-2-1_6b; unverified]
"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="stablelm-1.6b",
    family="dense",
    num_layers=24,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=5632,
    vocab_size=100352,
    activation="swiglu",
    norm="layernorm",
    rope_theta=10000.0,
    source="hf:stabilityai/stablelm-2-1_6b",
    verified="unverified",
))


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="stablelm-1.6b-reduced", num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=4, head_dim=16, d_ff=160, vocab_size=128)
