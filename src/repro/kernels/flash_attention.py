"""Pallas TPU kernel: causal flash attention (online softmax).

Grid: (B*H, Sq/Tq, Sk/Tk) with the KV dimension innermost; the running
max / denominator / accumulator live in VMEM scratch and persist across
KV grid steps (Pallas revisiting semantics).  Causal blocks entirely
above the diagonal are masked out; the final KV step normalizes and
writes the output tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale, tq, tk, sk_total, sq_total):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0]                                   # (Tq, hd)
    k = k_ref[0]                                   # (Tk, hd)
    v = v_ref[0]                                   # (Tk, hd)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    # causal mask in global coordinates (supports Sk >= Sq, aligned right)
    qpos = qi * tq + jax.lax.broadcasted_iota(jnp.int32, (tq, tk), 0)
    kpos = ki * tk + jax.lax.broadcasted_iota(jnp.int32, (tq, tk), 1)
    s = jnp.where(kpos <= qpos + (sk_total - sq_total), s, _NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1)
    acc_scr[...] = acc_scr[...] * corr[:, None] + jnp.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


def flash_attention_kernel(q, k, v, *, q_tile=128, k_tile=128,
                           interpret: bool | None = None):
    """q,k,v: (B,S,H,hd), kv pre-broadcast to H heads. Causal. -> (B,S,H,hd)."""
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    tq = min(q_tile, Sq)
    tk = min(k_tile, Sk)
    padq = (-Sq) % tq
    padk = (-Sk) % tk
    if padq:
        q = jnp.pad(q, ((0, 0), (0, padq), (0, 0), (0, 0)))
    if padk:
        k = jnp.pad(k, ((0, 0), (0, padk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, padk), (0, 0), (0, 0)))
    Sqp, Skp = Sq + padq, Sk + padk
    # (B,S,H,hd) -> (B*H, S, hd)
    qh = q.transpose(0, 2, 1, 3).reshape(B * H, Sqp, hd)
    kh = k.transpose(0, 2, 1, 3).reshape(B * H, Skp, hd)
    vh = v.transpose(0, 2, 1, 3).reshape(B * H, Skp, hd)

    grid = (B * H, Sqp // tq, Skp // tk)
    out = pl.pallas_call(
        functools.partial(_kernel, scale=1.0 / (hd ** 0.5), tq=tq, tk=tk,
                          sk_total=Sk, sq_total=Sq),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, tk, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, tk, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, tq, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sqp, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((tq,), jnp.float32),
            pltpu.VMEM((tq,), jnp.float32),
            pltpu.VMEM((tq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qh, kh, vh)
    out = out[:, :Sq].reshape(B, H, Sq, hd).transpose(0, 2, 1, 3)
    return out
