"""Pure-jnp oracles for every Pallas kernel (the correctness references)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# streamed_moe: grouped expert GEMM over one d_expert micro-slice
# ---------------------------------------------------------------------------

def streamed_moe_ref(xe, w_g, w_u, w_d, activation: str):
    """xe: (E,C,d); w_g/w_u: (E,d,m); w_d: (E,m,d) -> (E,C,d) fp32.

    ``w_g`` may be None for the gateless activations (relu2 / gelu)."""
    if activation == "swiglu":
        if w_g is None:
            raise ValueError("activation='swiglu' requires w_g")
        h = jax.nn.silu(jnp.einsum("ecd,edm->ecm", xe, w_g)) \
            * jnp.einsum("ecd,edm->ecm", xe, w_u)
    elif activation == "relu2":
        h = jnp.square(jax.nn.relu(jnp.einsum("ecd,edm->ecm", xe, w_u)))
    elif activation == "gelu":
        h = jax.nn.gelu(jnp.einsum("ecd,edm->ecm", xe, w_u))
    else:
        raise ValueError(activation)
    return jnp.einsum("ecm,emd->ecd", h, w_d).astype(jnp.float32)


def streamed_moe_quant_ref(xe, w_g, w_u, w_d, activation: str,
                           weight_dtype: str):
    """Quantized-streaming oracle: round-trip the expert weights through
    the streamed storage format (``kernels.quant.fake_quant`` — the
    identical per-(expert, output-channel) quantize→dequantize the
    Pallas kernel performs in VMEM), then run the exact fp32 einsum
    reference.  This is the ground truth the quantized kernel is tested
    against (tolerance contract: ``docs/quantization.md``)."""
    from . import quant
    return streamed_moe_ref(xe.astype(jnp.float32),
                            quant.fake_quant(w_g, weight_dtype),
                            quant.fake_quant(w_u, weight_dtype),
                            quant.fake_quant(w_d, weight_dtype),
                            activation)


# ---------------------------------------------------------------------------
# flash attention (causal)
# ---------------------------------------------------------------------------

def flash_attention_ref(q, k, v):
    """q,k,v: (B,S,H,hd) (kv already head-broadcast) -> (B,S,H,hd)."""
    hd = q.shape[-1]
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) / jnp.sqrt(jnp.float32(hd))
    Sq, Sk = q.shape[1], k.shape[1]
    mask = jnp.arange(Sk)[None, :] <= jnp.arange(Sq)[:, None] + (Sk - Sq)
    s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v)


# ---------------------------------------------------------------------------
# SSD intra-chunk (Mamba-2)
# ---------------------------------------------------------------------------

def _segsum(x):
    T = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    keep = jnp.tril(jnp.ones((T, T), bool), 0)
    return jnp.where(keep, out, -jnp.inf)


def ssd_intra_chunk_ref(xc, Bc, Cc, Ac, A_cumsum):
    """Intra-chunk SSD terms.

    xc: (b,nc,c,h,p); Bc/Cc: (b,nc,c,h,n); Ac/A_cumsum: (b,h,nc,c)
    Returns Y_diag (b,nc,c,h,p), states (b,nc,h,p,n)  — both fp32.
    """
    L = jnp.exp(_segsum(Ac))
    Y_diag = jnp.einsum("bclhn,bcshn,bhcls,bcshp->bclhp", Cc, Bc, L, xc)
    decay_states = jnp.exp(A_cumsum[:, :, :, -1:] - A_cumsum)
    states = jnp.einsum("bclhn,bhcl,bclhp->bchpn", Bc, decay_states, xc)
    return Y_diag.astype(jnp.float32), states.astype(jnp.float32)
