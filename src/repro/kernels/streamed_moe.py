"""Pallas TPU kernel: grouped expert GEMM over one d_expert micro-slice.

This is the compute hot-spot of FSE-DP's ring step (paper §IV): the
kernel body is the per-chiplet "SRAM" level of the adaptation — it
holds exactly **one weight micro-slice** (w_g/w_u: (d, m), w_d: (m, d))
plus one token tile in VMEM while computing the partial expert output,
mirroring the paper's claim that on-chip residency is one micro-slice
per stream.  HBM→VMEM pipelining across grid steps is Pallas's
automatic double-buffering of the BlockSpec'd operands (the DDR→SRAM
flow of Fig. 6); the D2D hop between chips is the ``ppermute`` in
``repro.core.fse_dp`` one level up.

Grid: (E, C/Tc, d/Tj, m/Tk, d/Ti) — experts outer so weight blocks are
revisited across token tiles of the same expert; the three inner dims
tile the output d_model (j), the micro-slice hidden dim (k) and the
contraction d_model (i) so micro-slices larger than one VMEM block
still lower.  The pre-activation accumulates in a VMEM scratch over
``i``; the second GEMM accumulates into the (revisited) output block
over ``k`` — both reduction dims are grid-minor, which is the Pallas
requirement for accumulate-safe block revisiting.  With the default
tile sizes (full d/m) the grid degenerates to the classic (E, C/Tc)
form.  Gateless activations (relu2 / gelu) lower without a w_gate
operand at all, so no placeholder slice is ever shipped HBM→VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


DEFAULT_TOKEN_TILE = 128
# auto-tiling target: keep one streamed weight block under this many bytes
# of VMEM (w_g + w_u + w_d + double-buffering must fit in ~16 MB/core)
VMEM_BLOCK_BYTES = 4 * 1024 * 1024


def fit_tile(dim: int, req: int) -> int:
    """Largest divisor of ``dim`` that is <= ``req`` (>= 1).

    The one tile-rounding rule shared by this kernel and the
    ``core.autotune`` planner, so requested tiles can never drift
    between what the planner costs and what the kernel lowers."""
    t = max(1, min(int(req), dim))
    while dim % t:
        t -= 1
    return t


_fit_tile = fit_tile  # backward-compat alias


def _kernel(*refs, activation, quantized, nI, C, Tc):
    gated = activation == "swiglu"
    n_in = (4 if gated else 3) + (quantized * (3 if gated else 2))
    x_ref, *w_refs = refs[:n_in]
    o_ref, hu_ref, *rest = refs[n_in:]
    hg_ref = rest[0] if gated else None
    if gated:
        wg_ref, wu_ref, wd_ref = w_refs[:3]
        sg_ref, su_ref, sd_ref = w_refs[3:] if quantized else (None,) * 3
    else:
        wu_ref, wd_ref = w_refs[:2]
        wg_ref = sg_ref = None
        su_ref, sd_ref = w_refs[2:] if quantized else (None, None)
    c = pl.program_id(1)
    k = pl.program_id(3)
    i = pl.program_id(4)

    @pl.when(i == 0)
    def _init_acc():
        hu_ref[...] = jnp.zeros_like(hu_ref)
        if hg_ref is not None:
            hg_ref[...] = jnp.zeros_like(hg_ref)

    def _load_up(w_ref, s_ref):
        w = w_ref[0]                  # (Ti, Tk) — int8/fp8 when quantized
        if s_ref is not None:
            # dequantize in VMEM: per-output-channel scale row (1,1,Tk)
            w = w.astype(jnp.float32) * s_ref[0, 0][None, :]
        return w

    x = x_ref[0]                      # (Tc, Ti)
    hu_ref[...] += jnp.dot(x, _load_up(wu_ref, su_ref),
                           preferred_element_type=jnp.float32)
    if hg_ref is not None:
        hg_ref[...] += jnp.dot(x, _load_up(wg_ref, sg_ref),
                               preferred_element_type=jnp.float32)

    @pl.when(i == nI - 1)
    def _finalize():
        if activation == "swiglu":
            h = jax.nn.silu(hg_ref[...]) * hu_ref[...]
        elif activation == "relu2":
            h = jnp.square(jnp.maximum(hu_ref[...], 0.0))
        else:  # gelu
            h = jax.nn.gelu(hu_ref[...])
        # mask padded capacity rows instead of computing garbage-then-truncate
        row = c * Tc + jax.lax.broadcasted_iota(jnp.int32, h.shape, 0)
        h = jnp.where(row < C, h, 0.0)
        wd = wd_ref[0]                # (Tk, Tj)
        if sd_ref is not None:
            wd = wd.astype(jnp.float32) * sd_ref[0, 0][None, :]
        contrib = jnp.dot(h.astype(wd.dtype), wd,
                          preferred_element_type=jnp.float32)

        @pl.when(k == 0)
        def _set():
            o_ref[0] = contrib

        @pl.when(k > 0)
        def _acc():
            o_ref[0] += contrib


def streamed_moe_kernel(xe, w_g, w_u, w_d, *, activation: str,
                        s_g=None, s_u=None, s_d=None,
                        token_tile: int = DEFAULT_TOKEN_TILE,
                        dmodel_tile: int | None = None,
                        dexpert_tile: int | None = None,
                        interpret: bool | None = None):
    """xe: (E,C,d); w_g: (E,d,m) or None; w_u: (E,d,m); w_d: (E,m,d).

    Returns (E,C,d) float32.  ``w_g`` is required for swiglu and ignored
    (never lowered as an operand) for the gateless activations.

    Quantized streaming: when ``s_u``/``s_d`` (and ``s_g`` for swiglu)
    are given, the weight operands are int8/fp8 with per-(expert,
    output-channel) fp32 scales — s_g/s_u: (E,1,m), s_d: (E,1,d)
    (``kernels.quant``).  Scale rows ship as (1,1,Tk)/(1,1,Tj) side
    blocks riding the same grid indices as their weight tile and are
    dequantized in VMEM right before each GEMM, so DDR->VMEM traffic is
    one byte per weight plus a ~1/d_in-sized scale stream.

    ``dmodel_tile`` tiles d_model on both sides of the expert FFN
    (contraction of the up-projection and output of the down-projection);
    ``dexpert_tile`` tiles the micro-slice hidden dim.  Defaults keep
    d_model whole and cap the hidden tile so one weight block stays under
    ``VMEM_BLOCK_BYTES``.  Requested tiles are rounded down to divisors.

    Trade-off: with ``dmodel_tile < d`` the up/gate GEMMs are recomputed
    once per output-d tile (the activation between the two GEMMs forces
    either that or an (Tc, m) h-scratch).  Keep d_model whole unless the
    weight blocks genuinely overflow VMEM.
    """
    E, C, d = xe.shape
    m = w_u.shape[-1]
    gated = activation == "swiglu"
    quantized = s_u is not None
    if gated and w_g is None:
        raise ValueError("activation='swiglu' requires w_g")
    if quantized and (s_d is None or (gated and s_g is None)):
        raise ValueError("quantized weights need scales for every operand")
    if activation not in ("swiglu", "relu2", "gelu"):
        raise ValueError(f"unknown activation {activation!r}")
    if interpret is None:
        # only TPU lowers this kernel natively (pltpu.VMEM scratch);
        # everything else (cpu, gpu) runs the interpreter
        interpret = jax.default_backend() != "tpu"

    Tc = min(token_tile, max(C, 1))
    pad = (-C) % Tc
    if pad:
        xe = jnp.pad(xe, ((0, 0), (0, pad), (0, 0)))
    Cp = C + pad

    itemsize = jnp.dtype(w_u.dtype).itemsize    # 1 for int8/fp8 operands
    if dexpert_tile is None:
        dexpert_tile = max(1, VMEM_BLOCK_BYTES // max(1, d * itemsize))
    Tk = fit_tile(m, dexpert_tile)
    Tj = Ti = fit_tile(d, dmodel_tile if dmodel_tile is not None else d)
    nI = d // Ti
    grid = (E, Cp // Tc, d // Tj, m // Tk, nI)

    in_specs = [pl.BlockSpec((1, Tc, Ti), lambda e, c, j, k, i: (e, c, i))]
    operands = [xe]
    if gated:
        in_specs.append(pl.BlockSpec((1, Ti, Tk), lambda e, c, j, k, i: (e, i, k)))
        operands.append(w_g)
    in_specs += [
        pl.BlockSpec((1, Ti, Tk), lambda e, c, j, k, i: (e, i, k)),   # w_up
        pl.BlockSpec((1, Tk, Tj), lambda e, c, j, k, i: (e, k, j)),   # w_down
    ]
    operands += [w_u, w_d]
    if quantized:
        # per-output-channel scale rows, block-indexed like their weights
        up_spec = pl.BlockSpec((1, 1, Tk), lambda e, c, j, k, i: (e, 0, k))
        if gated:
            in_specs.append(up_spec)
            operands.append(s_g)
        in_specs += [up_spec,
                     pl.BlockSpec((1, 1, Tj), lambda e, c, j, k, i: (e, 0, j))]
        operands += [s_u, s_d]
    scratch = [pltpu.VMEM((Tc, Tk), jnp.float32)]                     # pre-act up
    if gated:
        scratch.append(pltpu.VMEM((Tc, Tk), jnp.float32))             # pre-act gate

    out = pl.pallas_call(
        functools.partial(_kernel, activation=activation,
                          quantized=quantized, nI=nI, C=C, Tc=Tc),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, Tc, Tj), lambda e, c, j, k, i: (e, c, j)),
        out_shape=jax.ShapeDtypeStruct((E, Cp, d), jnp.float32),
        scratch_shapes=scratch,
        interpret=interpret,
    )(*operands)
    return out[:, :C]
