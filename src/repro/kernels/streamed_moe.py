"""Pallas TPU kernel: grouped expert GEMM over one d_expert micro-slice.

This is the compute hot-spot of FSE-DP's ring step (paper §IV): the
kernel body is the per-chiplet "SRAM" level of the adaptation — it
holds exactly **one weight micro-slice** (w_g/w_u: (d, m), w_d: (m, d))
plus one token tile in VMEM while computing the partial expert output,
mirroring the paper's claim that on-chip residency is one micro-slice
per stream.  HBM→VMEM pipelining across grid steps is Pallas's
automatic double-buffering of the BlockSpec'd operands (the DDR→SRAM
flow of Fig. 6); the D2D hop between chips is the ``ppermute`` in
``repro.core.fse_dp`` one level up.

Grid: (E, C/Tc) — experts outer so weight blocks are revisited across
token tiles of the same expert; token tiles inner.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_TOKEN_TILE = 128


def _kernel(x_ref, wg_ref, wu_ref, wd_ref, o_ref, *, activation):
    x = x_ref[0]                      # (Tc, d)
    wu = wu_ref[0]                    # (d, m)
    if activation == "swiglu":
        wg = wg_ref[0]
        h = jax.nn.silu(jnp.dot(x, wg, preferred_element_type=jnp.float32)) \
            * jnp.dot(x, wu, preferred_element_type=jnp.float32)
    elif activation == "relu2":
        h = jnp.square(jnp.maximum(
            jnp.dot(x, wu, preferred_element_type=jnp.float32), 0.0))
    else:  # gelu
        h = jax.nn.gelu(jnp.dot(x, wu, preferred_element_type=jnp.float32))
    wd = wd_ref[0]                    # (m, d)
    o_ref[0] = jnp.dot(h.astype(wd.dtype), wd,
                       preferred_element_type=jnp.float32)


def streamed_moe_kernel(xe, w_g, w_u, w_d, *, activation: str,
                        token_tile: int = DEFAULT_TOKEN_TILE,
                        interpret: bool | None = None):
    """xe: (E,C,d); w_g/w_u: (E,d,m); w_d: (E,m,d) -> (E,C,d) float32."""
    E, C, d = xe.shape
    m = w_u.shape[-1]
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    Tc = min(token_tile, C)
    pad = (-C) % Tc
    if pad:
        xe = jnp.pad(xe, ((0, 0), (0, pad), (0, 0)))
    Cp = C + pad
    grid = (E, Cp // Tc)

    if activation != "swiglu":
        w_g = w_u  # placeholder operand; kernel ignores it

    out = pl.pallas_call(
        functools.partial(_kernel, activation=activation),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Tc, d), lambda e, c: (e, c, 0)),   # token tile
            pl.BlockSpec((1, d, m), lambda e, c: (e, 0, 0)),    # w_gate slice
            pl.BlockSpec((1, d, m), lambda e, c: (e, 0, 0)),    # w_up slice
            pl.BlockSpec((1, m, d), lambda e, c: (e, 0, 0)),    # w_down slice
        ],
        out_specs=pl.BlockSpec((1, Tc, d), lambda e, c: (e, c, 0)),
        out_shape=jax.ShapeDtypeStruct((E, Cp, d), jnp.float32),
        interpret=interpret,
    )(xe, w_g, w_u, w_d)
    return out[:, :C]
