"""jit'd public wrappers around the Pallas kernels with ref fallbacks.

``use_kernels(False)`` (or the REPRO_NO_PALLAS env var) routes every op
to its pure-jnp oracle — the dry-run path uses this so the 512-device
SPMD compile sees plain XLA ops.
"""
from __future__ import annotations

import contextlib
import contextvars
import os

import jax

from . import ref
from .streamed_moe import streamed_moe_kernel
from .flash_attention import flash_attention_kernel
from .ssd import ssd_intra_chunk_kernel

_USE = contextvars.ContextVar("repro_use_pallas",
                              default=not bool(os.environ.get("REPRO_NO_PALLAS")))


@contextlib.contextmanager
def use_kernels(enabled: bool):
    tok = _USE.set(enabled)
    try:
        yield
    finally:
        _USE.reset(tok)


def kernels_enabled() -> bool:
    return _USE.get()


def streamed_moe(xe, w_g, w_u, w_d, activation: str, **kw):
    if kernels_enabled():
        return streamed_moe_kernel(xe, w_g if w_g is not None else w_u,
                                   w_u, w_d, activation=activation, **kw)
    return ref.streamed_moe_ref(xe, w_g, w_u, w_d, activation)


def flash_attention(q, k, v, **kw):
    if kernels_enabled():
        return flash_attention_kernel(q, k, v, **kw)
    return ref.flash_attention_ref(q, k, v)


def ssd_intra_chunk(xc, Bc, Cc, Ac, A_cumsum, **kw):
    if kernels_enabled():
        return ssd_intra_chunk_kernel(xc, Bc, Cc, Ac, A_cumsum, **kw)
    return ref.ssd_intra_chunk_ref(xc, Bc, Cc, Ac, A_cumsum)
