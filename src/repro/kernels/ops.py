"""jit'd public wrappers around the Pallas kernels with ref fallbacks.

``use_kernels(False)`` (or the REPRO_NO_PALLAS env var) routes every op
to its pure-jnp oracle — the dry-run path uses this so the 512-device
SPMD compile sees plain XLA ops.

``streamed_moe`` is the dispatch layer the model code calls: both the
FSE-DP ring step (``repro.core.fse_dp._expert_partial``) and the
single-device capacity path (``repro.models.moe.moe_capacity``) flow
through it, so the paper's micro-slice kernel is the hot path whenever
kernels are enabled.  The Pallas branch carries a custom VJP (backward
derived from the jnp oracle) so gradients flow through training and the
FSE-DP ring transpose without a hand-written backward kernel.
"""
from __future__ import annotations

import contextlib
import contextvars
import os

import jax

from . import quant, ref
from .streamed_moe import streamed_moe_kernel
from .flash_attention import flash_attention_kernel
from .ssd import ssd_intra_chunk_kernel

_USE = contextvars.ContextVar("repro_use_pallas",
                              default=not bool(os.environ.get("REPRO_NO_PALLAS")))


@contextlib.contextmanager
def use_kernels(enabled: bool):
    tok = _USE.set(enabled)
    try:
        yield
    finally:
        _USE.reset(tok)


def kernels_enabled() -> bool:
    return _USE.get()


# ---------------------------------------------------------------------------
# streamed_moe — differentiable kernel dispatch
# ---------------------------------------------------------------------------

def _streamed_moe_raw(activation, weight_dtype, opts, xe, w_g, w_u, w_d):
    if weight_dtype in quant.QUANTIZED:
        # quantize in-graph at the dispatch layer: params keep their
        # original dtype; the kernel streams int8/fp8 blocks plus
        # per-(expert, output-channel) scale rows and dequantizes in VMEM
        s_g = None
        if w_g is not None:
            w_g, s_g = quant.quantize(w_g, weight_dtype)
        w_u, s_u = quant.quantize(w_u, weight_dtype)
        w_d, s_d = quant.quantize(w_d, weight_dtype)
        return streamed_moe_kernel(xe, w_g, w_u, w_d, activation=activation,
                                   s_g=s_g, s_u=s_u, s_d=s_d, **dict(opts))
    return streamed_moe_kernel(xe, quant.storage_cast(w_g, weight_dtype),
                               quant.storage_cast(w_u, weight_dtype),
                               quant.storage_cast(w_d, weight_dtype),
                               activation=activation, **dict(opts))


_streamed_moe_diff = jax.custom_vjp(_streamed_moe_raw,
                                    nondiff_argnums=(0, 1, 2))


def _streamed_moe_fwd(activation, weight_dtype, opts, xe, w_g, w_u, w_d):
    out = _streamed_moe_raw(activation, weight_dtype, opts, xe, w_g, w_u, w_d)
    return out, (xe, w_g, w_u, w_d)


def _streamed_moe_bwd(activation, weight_dtype, opts, res, g):
    # straight-through: the backward of the quantized forward is the
    # full-precision oracle VJP on the original weights
    xe, w_g, w_u, w_d = res
    _, vjp = jax.vjp(
        lambda xe, wg, wu, wd: ref.streamed_moe_ref(xe, wg, wu, wd, activation),
        xe, w_g, w_u, w_d)
    return vjp(g)


_streamed_moe_diff.defvjp(_streamed_moe_fwd, _streamed_moe_bwd)


def streamed_moe(xe, w_g, w_u, w_d, activation: str, **kw):
    """Grouped expert FFN over one micro-slice.  ``w_g=None`` selects the
    gateless path natively (no placeholder operand).

    ``weight_dtype`` (kwarg or the ambient ``quant.use_weight_dtype``
    context, entered by ``ExecutionSpec.scope()``) selects the streamed
    storage format for the expert weights: int8/fp8 quantize in-graph
    with per-(expert, output-channel) scales; the oracle fallback runs
    the identical quantize→dequantize round-trip, so ``use_kernels(False)``
    stays the ground truth at any weight dtype."""
    kw = dict(kw)
    wdt = quant.check_weight_dtype(kw.pop("weight_dtype", None))
    if wdt is None:
        wdt = quant.weight_dtype()
    if not kernels_enabled():
        if wdt is None:
            return ref.streamed_moe_ref(xe, w_g, w_u, w_d, activation)
        return ref.streamed_moe_quant_ref(xe, w_g, w_u, w_d, activation, wdt)
    opts = tuple(sorted(kw.items()))
    return _streamed_moe_diff(activation, wdt, opts, xe, w_g, w_u, w_d)


def streamed_moe_autotuned(xe, w_g, w_u, w_d, activation: str):
    """``streamed_moe`` with tile kwargs chosen by the ``core.autotune``
    planner for this call's (E, C, d, m) shape, honoring the ambient
    autotune level — ``off`` (kernel defaults, the pre-autotuner
    lowering), ``analytic`` (cost-model tiles), or ``measured``
    (wall-clock-timed tiles memoized under ``artifacts/autotune/``).

    This is the one scheduler every expert-FFN path dispatches through:
    the FSE-DP ring step, the EP/TP baselines, and the single-device
    capacity path.  The ambient weight dtype feeds the planner its
    streamed bytes-per-param, so quantized runs plan (and cost) larger
    hidden tiles per VMEM block."""
    opts = {}
    if kernels_enabled():
        from repro.core import autotune
        E, C, d = xe.shape
        m = w_u.shape[-1]
        stored = jax.numpy.dtype(w_u.dtype).itemsize
        opts = autotune.kernel_opts_for(
            E, C, d, m, activation, dtype_bytes=stored,
            weight_bytes=quant.weight_bytes(default=stored))
    return streamed_moe(xe, w_g, w_u, w_d, activation, **opts)


def flash_attention(q, k, v, **kw):
    if kernels_enabled():
        return flash_attention_kernel(q, k, v, **kw)
    return ref.flash_attention_ref(q, k, v)


def ssd_intra_chunk(xc, Bc, Cc, Ac, A_cumsum, **kw):
    if kernels_enabled():
        return ssd_intra_chunk_kernel(xc, Bc, Cc, Ac, A_cumsum, **kw)
    return ref.ssd_intra_chunk_ref(xc, Bc, Cc, Ac, A_cumsum)
