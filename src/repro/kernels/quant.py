"""Weight quantization for expert streaming (the streamed storage format).

The paper's bottleneck is moving expert weights over DDR/D2D at low
batch, so bytes-per-param multiplies directly into every cost the
trajectory scheduler and autotuner model.  This module defines the
*streamed* storage format for expert FFN weights — independent of the
parameter dtype the model was initialized with:

  fp32 / bf16  — plain storage (bf16 is a round-trip cast when params
                 are wider), 4 / 2 bytes per param;
  int8         — symmetric, per-(expert, output-channel) scales,
                 q = round(w / s) clipped to [-127, 127], 1 byte;
  fp8          — ``float8_e4m3fn`` with the same per-channel scaling
                 (absmax mapped to the fp8 max, 448), 1 byte.

Scales are computed over the contraction axis (axis -2 of the stacked
(E, d_in, d_out) weight), giving one fp32 scale per (expert, output
channel): shape (E, 1, d_out).  That granularity ships as a tiny side
operand next to each weight block in the Pallas kernel — (1, 1, Tk)
blocks riding the same grid indices as the weight tile — and
dequantizes in VMEM before the GEMM.

Quantization happens **in-graph at the dispatch layer**
(``kernels.ops.streamed_moe``): params keep their original dtype, so
shard_map partition specs, optimizer state, and checkpoints never
change.  The jnp oracle applies the identical quantize→dequantize
round-trip, so ``use_kernels(False)`` stays the ground truth under any
weight dtype (tolerance contract: ``docs/quantization.md``).

The ambient weight dtype is a contextvar (like ``ops.use_kernels``),
entered by ``ExecutionSpec.scope()`` so one spec field threads the
format end-to-end through every execution body.
"""
from __future__ import annotations

import contextlib
import contextvars

import jax.numpy as jnp

# streamed bytes per parameter for each supported format
WEIGHT_DTYPES = {"fp32": 4, "bf16": 2, "int8": 1, "fp8": 1}
# formats that ship a per-channel scale side operand
QUANTIZED = ("int8", "fp8")

INT8_MAX = 127.0
FP8_DTYPE = jnp.float8_e4m3fn
FP8_MAX = 448.0           # float8_e4m3fn finfo.max

_WDT = contextvars.ContextVar("repro_weight_dtype", default=None)


def check_weight_dtype(name):
    if name is not None and name not in WEIGHT_DTYPES:
        raise ValueError(f"unknown weight_dtype {name!r}; "
                         f"known: {sorted(WEIGHT_DTYPES)}")
    return name


@contextlib.contextmanager
def use_weight_dtype(name):
    """Ambient streamed-weight format for ``kernels.ops.streamed_moe``
    dispatch (``None`` = params as-is, the untouched default)."""
    tok = _WDT.set(check_weight_dtype(name))
    try:
        yield
    finally:
        _WDT.reset(tok)


def weight_dtype():
    """The ambient streamed-weight format name, or None."""
    return _WDT.get()


def weight_bytes(name=None, default=None):
    """Streamed bytes per param for ``name`` (or the ambient format);
    ``default`` when neither is set."""
    if name is None:
        name = _WDT.get()
    if name is None:
        return default
    return WEIGHT_DTYPES[check_weight_dtype(name)]


def quantize(w, name):
    """w: (..., d_in, d_out) -> (q, scale) with per-(leading, out-channel)
    symmetric scales of shape (..., 1, d_out) fp32."""
    wf = w.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(wf), axis=-2, keepdims=True)
    if name == "int8":
        scale = jnp.where(absmax > 0, absmax, 1.0) / INT8_MAX
        q = jnp.clip(jnp.round(wf / scale), -INT8_MAX, INT8_MAX)
        return q.astype(jnp.int8), scale
    if name == "fp8":
        scale = jnp.where(absmax > 0, absmax, 1.0) / FP8_MAX
        return (wf / scale).astype(FP8_DTYPE), scale
    raise ValueError(f"not a quantized weight_dtype: {name!r}")


def dequantize(q, scale):
    """Inverse of :func:`quantize` — fp32 values (lossy round-trip)."""
    return q.astype(jnp.float32) * scale


def storage_cast(w, name):
    """The unquantized formats: cast ``w`` to its streamed storage dtype
    (identity for fp32 params under 'fp32')."""
    if w is None:
        return None
    if name == "bf16":
        return w.astype(jnp.bfloat16)
    if name in (None, "fp32"):
        return w
    raise ValueError(f"not a storage-cast weight_dtype: {name!r}")


def fake_quant(w, name):
    """Round-trip ``w`` through the streamed format, returned as fp32 —
    the oracle-side view of what the kernel computes with."""
    if w is None:
        return None
    if name in QUANTIZED:
        return dequantize(*quantize(w, name))
    return storage_cast(w, name).astype(jnp.float32)
