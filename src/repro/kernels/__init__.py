from . import ops, ref
from . import ops as flash_ops   # alias used by models.attention
from . import ops as ssd_ops     # alias used by models.mamba2
from .ops import streamed_moe, streamed_moe_autotuned, flash_attention, ssd_intra_chunk, use_kernels, kernels_enabled
