"""Pallas TPU kernel: Mamba-2 SSD intra-chunk terms.

For each (batch·chunk, head) grid cell the kernel holds one chunk of
X/B/C plus the per-chunk decay row in VMEM and produces the
intra-chunk output term and the chunk's contribution to the inter-chunk
state.  The O(c²) semiseparable mask L = exp(segsum(A)) is built with
iota inside the kernel (no HBM traffic for the mask).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_NEG_INF = -1e30


def _kernel(x_ref, b_ref, c_ref, a_ref, acum_ref, y_ref, st_ref, *, chunk):
    x = x_ref[0, 0, :, 0, :].astype(jnp.float32)        # (c, p)
    Bm = b_ref[0, 0, :, 0, :].astype(jnp.float32)       # (c, n)
    Cm = c_ref[0, 0, :, 0, :].astype(jnp.float32)       # (c, n)
    acum = acum_ref[0, 0, 0, :].astype(jnp.float32)     # (c,)

    # L[i, j] = exp(acum[i] - acum[j]) for i >= j else 0
    diff = acum[:, None] - acum[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(ii >= jj, jnp.exp(diff), 0.0)

    scores = jnp.dot(Cm, Bm.T, preferred_element_type=jnp.float32) * L  # (c,c)
    y_ref[0, 0, :, 0, :] = jnp.dot(scores, x, preferred_element_type=jnp.float32)

    decay = jnp.exp(acum[-1] - acum)                    # (c,)
    st_ref[0, 0, 0] = jnp.dot((Bm * decay[:, None]).T, x,
                              preferred_element_type=jnp.float32).T  # (p,n)


def ssd_intra_chunk_kernel(xc, Bc, Cc, Ac, A_cumsum, *, interpret: bool | None = None):
    """xc: (b,nc,c,h,p); Bc/Cc: (b,nc,c,h,n); Ac/A_cumsum: (b,h,nc,c).

    Returns (Y_diag (b,nc,c,h,p), states (b,nc,h,p,n)) in fp32.
    """
    b, nc, c, h, p = xc.shape
    n = Bc.shape[-1]
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    grid = (b, nc, h)

    y, st = pl.pallas_call(
        functools.partial(_kernel, chunk=c),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, c, 1, p), lambda i, j, k: (i, j, 0, k, 0)),
            pl.BlockSpec((1, 1, c, 1, n), lambda i, j, k: (i, j, 0, k, 0)),
            pl.BlockSpec((1, 1, c, 1, n), lambda i, j, k: (i, j, 0, k, 0)),
            pl.BlockSpec((1, 1, 1, c), lambda i, j, k: (i, k, j, 0)),   # Ac (b,h,nc,c)
            pl.BlockSpec((1, 1, 1, c), lambda i, j, k: (i, k, j, 0)),   # A_cumsum
        ],
        out_specs=[
            pl.BlockSpec((1, 1, c, 1, p), lambda i, j, k: (i, j, 0, k, 0)),
            pl.BlockSpec((1, 1, 1, p, n), lambda i, j, k: (i, j, k, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, nc, c, h, p), jnp.float32),
            jax.ShapeDtypeStruct((b, nc, h, p, n), jnp.float32),
        ],
        interpret=interpret,
    )(xc, Bc, Cc, Ac, A_cumsum)
    return y, st
