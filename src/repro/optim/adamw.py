"""AdamW with global-norm clipping, cosine schedule, and a low-precision
state option (bf16 m/v) used by the huge-arch dry-runs to fit HBM."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: dict
    v: dict


def init(params, state_dtype=jnp.float32) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, state_dtype)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params))


def cosine_schedule(base_lr: float, warmup: int, total: int):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / jnp.maximum(1.0, warmup)
        frac = jnp.clip((step - warmup) / jnp.maximum(1.0, total - warmup), 0.0, 1.0)
        cos = 0.5 * base_lr * (1.0 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, cos)
    return lr


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    g = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(g, 1e-9))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * scale).astype(x.dtype), grads), g


def apply(params, grads, state: AdamWState, *, lr, b1=0.9, b2=0.95,
          eps=1e-8, weight_decay=0.1, max_grad_norm=1.0):
    """One AdamW update. ``lr`` is a scalar or a schedule(step)->scalar."""
    if callable(lr):
        lr = lr(state.step)
    grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
    step = state.step + 1
    b1c = 1.0 - b1 ** step.astype(jnp.float32)
    b2c = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m2 = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        v2 = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
        mhat = m2 / b1c
        vhat = v2 / b2c
        pf = p.astype(jnp.float32)
        pf = pf - lr * (mhat / (jnp.sqrt(vhat) + eps) + weight_decay * pf)
        return pf.astype(p.dtype), m2.astype(m.dtype), v2.astype(v.dtype)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    return new_p, AdamWState(step, new_m, new_v), {"grad_norm": gnorm}
