from . import adamw, compress
