"""Int8 gradient compression with error feedback (cross-pod all-reduce).

Beyond-paper distributed-optimization trick: quantize gradients to int8
per-tensor-scale before the (slow, DCN-crossing) ``pod``-axis
all-reduce, carrying the quantization residual into the next step
(error feedback keeps SGD/Adam convergence unbiased in practice).

The quantize/dequantize pair is exact enough that the trainer test
asserts convergence parity within tolerance on a small model.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize(g, *, bits: int = 8):
    """g -> (q int8, scale). Symmetric per-tensor scaling."""
    lim = 2.0 ** (bits - 1) - 1
    gf = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / lim
    q = jnp.clip(jnp.round(gf / scale), -lim, lim).astype(jnp.int8)
    return q, scale


def dequantize(q, scale):
    return q.astype(jnp.float32) * scale


def compress_tree(grads, residual=None, *, bits: int = 8):
    """Returns (decompressed grads, new residual). With error feedback:
    q = Q(g + r);  r' = (g + r) - deQ(q)."""
    if residual is None:
        residual = jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    def one(g, r):
        corrected = g.astype(jnp.float32) + r
        q, s = quantize(corrected, bits=bits)
        deq = dequantize(q, s)
        return deq.astype(g.dtype), corrected - deq

    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residual)
    pairs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    deq = jax.tree.unflatten(tdef, [p_[0] for p_ in pairs])
    res = jax.tree.unflatten(tdef, [p_[1] for p_ in pairs])
    return deq, res


def compressed_bytes_ratio(bits: int = 8, dtype_bits: int = 32) -> float:
    return bits / dtype_bits
