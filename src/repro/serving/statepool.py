"""Paged KV / SSM state pool: page table, prefix cache, preemption.

The engine's sequence state used to be one dense ``max_ctx`` cache slab
pinned per slot for the request's whole lifetime.  This module replaces
that with a **block/paged pool** (the vLLM PagedAttention idea, adapted
to the hybrid attn/SSM stacks this repo serves):

* **Attention KV** lives in fixed-size physical pages
  (``page_size`` tokens each, ``num_pages`` total per engine) shared by
  every serving slot.  One host-side page table (``(max_batch, NP)``
  int32, ``NP = ceil(max_ctx / page_size)``) maps logical to physical
  pages — a *single* table serves every layer because page allocation
  advances in lockstep across layers.  The table is pushed to the
  device once per engine iteration and enters the jitted mega-steps as
  a traced argument, so allocation churn never retraces.
* **Mamba2 conv/ssm state** is O(1) per slot, so it stays dense per
  row; the pool snapshots it *by value* at chunk boundaries
  (``models.mamba2.ssm_state_slice`` — plain slices, so snapshot ->
  restore is bit-exact).

On top of the pool sit two behaviors:

* **Prefix caching** — after each prefill chunk the engine registers
  the slot's state under a content hash of the prompt-prefix *chain*
  (``h_i = sha256(h_{i-1} || token_i)``, keyed at every chunk
  boundary).  A later request that shares a cached prefix admits with
  the prefix's pages attached (full pages shared by refcount, the
  partial tail page copied — copy-on-write, since decode will write
  into it) and the SSM snapshot restored; only the unshared suffix is
  computed.  Per-token outputs are chunk-partition-invariant under
  ``drop_free`` (PR 5's batching-invariance property), so cache-hit
  runs stay bit-identical to cold sequential runs.
* **Preemption** — a request whose sub-layer progress is at an
  iteration boundary can be evicted to a :class:`PreemptedState`
  handle: the page-table row detaches in O(1) (no data movement — page
  refs transfer to the handle) and the SSM rows snapshot by value.
  Restoring into any free slot re-attaches the pages and writes the
  snapshot back — bit-identical resumption, asserted by tests.

Page lifecycle is refcounted: a page is freed only when no slot row,
prefix-cache entry, or preemption handle references it.  Prefix entries
are evicted LRU — on explicit pressure (``max_prefix_entries``) and on
demand when the free list runs dry; :class:`PoolExhausted` is raised
only when eviction cannot recover enough pages (active slots + handles
hold everything).

See docs/statepool.md for the design discussion and the accounting
fields surfaced in ``Engine.stats``.
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict, deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import mamba2 as ssm_mod
from repro.models.attention import KVCache


class PoolExhausted(RuntimeError):
    """No free pages left after evicting every evictable prefix entry."""


def hash_chain(tokens) -> List[bytes]:
    """Content-hash chain over a token sequence.

    ``out[i]`` identifies the prefix ``tokens[:i+1]`` — equal prefixes
    give equal keys regardless of which request produced them, and the
    chain construction makes every key depend on the full prefix, not
    just its last chunk."""
    h = hashlib.sha256()
    out: List[bytes] = []
    for t in tokens:
        h.update(int(t).to_bytes(8, "little", signed=True))
        out.append(h.digest())
    return out


@dataclass
class PrefixEntry:
    """One cached prompt prefix: shared full pages + owned tail copy."""
    key: bytes
    length: int                      # tokens covered
    page_ids: List[int]              # ceil(length / page_size) refcounted ids
    ssm: tuple = ()                  # per-layer SSMState snapshots (or ())
    hits: int = 0


@dataclass
class PreemptedState:
    """Everything needed to resume an evicted request bit-identically."""
    request: object                  # engine RequestState (progress == 0)
    page_ids: List[int]              # ownership transferred from the slot row
    cache_len: int
    ssm: tuple = ()


class StatePool:
    """Host-side metadata manager for the paged serving state.

    Owns the free list, refcounts, per-slot page lists, the page table,
    and the prefix-cache LRU.  Device arrays are owned by the engine;
    methods that need data movement (partial-page copy-on-write, SSM
    snapshot/restore) return instructions or take/yield snapshots, and
    the engine applies them with the module-level array helpers below.
    """

    def __init__(self, *, max_batch: int, max_ctx: int, page_size: int,
                 num_pages: Optional[int] = None,
                 max_prefix_entries: int = 64,
                 bytes_per_page: int = 0, ssm_bytes_per_row: int = 0):
        assert page_size >= 1 and max_ctx >= 1
        self.page_size = page_size
        self.pages_per_slot = -(-max_ctx // page_size)
        # default headroom: every slot full twice over — half live, half
        # available to prefix entries / preemption handles
        self.num_pages = (num_pages if num_pages is not None
                          else 2 * max_batch * self.pages_per_slot)
        if self.num_pages < max_batch * self.pages_per_slot:
            raise ValueError(
                f"state pool too small: {self.num_pages} pages < "
                f"{max_batch} slots x {self.pages_per_slot} pages/slot — "
                f"active slots alone could exhaust it")
        self.max_prefix_entries = max_prefix_entries
        self.bytes_per_page = bytes_per_page
        self.ssm_bytes_per_row = ssm_bytes_per_row
        self.table = np.zeros((max_batch, self.pages_per_slot), np.int32)
        self.free: Deque[int] = deque(range(self.num_pages))
        self.ref = np.zeros((self.num_pages,), np.int64)
        self.slot_pages: List[List[int]] = [[] for _ in range(max_batch)]
        self.entries: "OrderedDict[bytes, PrefixEntry]" = OrderedDict()
        self._ssm_rows_held = 0          # snapshots held by entries+handles
        self.stats: Dict[str, int] = {
            "pool_pages": self.num_pages,
            "pool_pages_in_use": 0, "pool_peak_pages": 0,
            "resident_state_bytes": 0, "peak_resident_state_bytes": 0,
            "cache_hits": 0, "cache_misses": 0, "cache_evictions": 0,
            "prefill_tokens_saved": 0,
        }

    # ------------------------------------------------------------------
    # page bookkeeping
    # ------------------------------------------------------------------

    def pages_in_use(self) -> int:
        return self.num_pages - len(self.free)

    def _account(self) -> None:
        used = self.pages_in_use()
        self.stats["pool_pages_in_use"] = used
        self.stats["pool_peak_pages"] = max(self.stats["pool_peak_pages"],
                                            used)
        resident = (used * self.bytes_per_page
                    + self._ssm_rows_held * self.ssm_bytes_per_row)
        self.stats["resident_state_bytes"] = resident
        self.stats["peak_resident_state_bytes"] = max(
            self.stats["peak_resident_state_bytes"], resident)

    def _alloc(self, n: int) -> List[int]:
        while len(self.free) < n and self.entries:
            self._evict_lru()
        if len(self.free) < n:
            raise PoolExhausted(
                f"state pool exhausted: need {n} pages, "
                f"{len(self.free)} free of {self.num_pages} "
                f"(active slots and preemption handles hold the rest)")
        ids = [self.free.popleft() for _ in range(n)]
        for pid in ids:
            self.ref[pid] = 1
        self._account()
        return ids

    def _addref(self, pid: int) -> None:
        self.ref[pid] += 1

    def _deref(self, pid: int) -> None:
        self.ref[pid] -= 1
        assert self.ref[pid] >= 0, f"page {pid} refcount underflow"
        if self.ref[pid] == 0:
            self.free.append(pid)

    # ------------------------------------------------------------------
    # slot lifecycle
    # ------------------------------------------------------------------

    def ensure(self, slot: int, length: int) -> None:
        """Grow ``slot``'s page run to cover ``length`` tokens.

        Called on the host *before* each engine iteration (the table
        must be read-only inside the jitted step), so capacity exists
        for every KV write the coming iteration performs."""
        need = -(-length // self.page_size)
        have = len(self.slot_pages[slot])
        if need <= have:
            return
        ids = self._alloc(need - have)
        self.table[slot, have:need] = ids
        self.slot_pages[slot].extend(ids)

    def release_slot(self, slot: int) -> None:
        """Drop the slot row's references (request finished/cancelled).
        Pages shared with prefix entries survive via their refcounts."""
        for pid in self.slot_pages[slot]:
            self._deref(pid)
        self.slot_pages[slot] = []
        self._account()

    # ------------------------------------------------------------------
    # prefix cache
    # ------------------------------------------------------------------

    def lookup_prefix(self, keys: List[bytes],
                      max_len: int) -> Optional[PrefixEntry]:
        """Longest cached prefix of a prompt, capped at ``max_len``
        tokens (the engine passes ``len(prompt) - 1`` — at least one
        prompt token must run so first-token logits exist)."""
        best: Optional[PrefixEntry] = None
        for L in range(min(max_len, len(keys)), 0, -1):
            e = self.entries.get(keys[L - 1])
            if e is not None and e.length == L:
                best = e
                break
        if best is not None:
            self.entries.move_to_end(best.key)
            best.hits += 1
        return best

    def register_prefix(self, key: bytes, length: int, slot: int,
                        ssm: tuple = ()) -> Optional[Tuple[int, int]]:
        """Register the first ``length`` cached tokens of ``slot``.

        Full pages are shared by reference; a partial tail page needs a
        copy (decode will keep writing into the slot's own tail), so
        the pool allocates a destination and returns ``(src, dst)`` for
        the engine to copy on-device (:func:`copy_page`).  Returns None
        when nothing needs copying or the key is already cached."""
        if key in self.entries:
            self.entries.move_to_end(key)
            return None
        n_full, tail = divmod(length, self.page_size)
        row = self.slot_pages[slot]
        assert len(row) >= n_full + (1 if tail else 0), \
            f"slot {slot} holds {len(row)} pages, prefix needs {length} tokens"
        ids = list(row[:n_full])
        for pid in ids:
            self._addref(pid)
        copy = None
        if tail:
            dst = self._alloc(1)[0]
            ids.append(dst)
            copy = (row[n_full], dst)
        self.entries[key] = PrefixEntry(key=key, length=length,
                                        page_ids=ids, ssm=ssm)
        if ssm != ():
            self._ssm_rows_held += 1
        while len(self.entries) > self.max_prefix_entries:
            self._evict_lru()
        self._account()
        return copy

    def attach_prefix(self, entry: PrefixEntry,
                      slot: int) -> Optional[Tuple[int, int]]:
        """Point ``slot``'s table row at a cached prefix.

        Full pages are shared (refcount+1) — safe because the slot only
        ever writes at positions >= entry.length, which land beyond
        them.  A partial tail page is copied into a fresh page the slot
        owns (returned as ``(src, dst)`` for the engine to copy)."""
        assert not self.slot_pages[slot], \
            f"attach_prefix into non-empty slot {slot}"
        n_full, tail = divmod(entry.length, self.page_size)
        ids = list(entry.page_ids[:n_full])
        for pid in ids:
            self._addref(pid)
        copy = None
        if tail:
            dst = self._alloc(1)[0]
            copy = (entry.page_ids[n_full], dst)
            ids.append(dst)
        self.table[slot, :len(ids)] = ids
        self.slot_pages[slot] = ids
        self.stats["cache_hits"] += 1
        self.stats["prefill_tokens_saved"] += entry.length
        self._account()
        return copy

    def _evict_lru(self) -> None:
        key, entry = self.entries.popitem(last=False)
        for pid in entry.page_ids:
            self._deref(pid)
        if entry.ssm != ():
            self._ssm_rows_held -= 1
        self.stats["cache_evictions"] += 1
        self._account()

    # ------------------------------------------------------------------
    # preemption
    # ------------------------------------------------------------------

    def detach_slot(self, slot: int, *, has_ssm: bool = False) -> List[int]:
        """Transfer the slot row's page ownership to a preemption handle
        (no refcount change — the handle now holds the row's refs).

        ``has_ssm`` marks a handle that actually snapshots SSM state
        (hybrid/Mamba2 models): only those hold an off-slot SSM row.
        A pure-attention preemption must not inflate the SSM-row
        accounting (and with it ``resident_state_bytes``)."""
        ids = self.slot_pages[slot]
        self.slot_pages[slot] = []
        if has_ssm:
            self._ssm_rows_held += 1
        self._account()
        return ids

    def attach_pages(self, slot: int, page_ids: List[int], *,
                     has_ssm: bool = False) -> None:
        """Re-attach a preemption handle's pages to a (fresh) slot row.
        ``has_ssm`` as in :meth:`detach_slot` — releases the handle's
        SSM row only if the handle held one."""
        assert not self.slot_pages[slot], \
            f"attach_pages into non-empty slot {slot}"
        if len(page_ids) > self.pages_per_slot:
            raise ValueError(f"{len(page_ids)} pages exceed the "
                             f"{self.pages_per_slot}-page slot row")
        self.table[slot, :len(page_ids)] = page_ids
        self.slot_pages[slot] = list(page_ids)
        if has_ssm:
            self._ssm_rows_held -= 1
        self._account()

    def drop_handle(self, handle: PreemptedState) -> None:
        """Discard a preemption handle (requeue-mode: state is thrown
        away and the request restarts from its prompt)."""
        for pid in handle.page_ids:
            self._deref(pid)
        if handle.ssm != ():
            self._ssm_rows_held -= 1
        self._account()


# ---------------------------------------------------------------------------
# device-array helpers (applied by the engine; the pool stays host-only)
# ---------------------------------------------------------------------------


def _is_ssm(part) -> bool:
    return isinstance(part, ssm_mod.SSMState)


def copy_page(caches, src: int, dst: int):
    """Copy one physical page across every attention layer (the
    copy-on-write step for partial tail pages)."""
    out = []
    for c in caches:
        if isinstance(c.kv, KVCache):
            kv = jax.tree.map(lambda a: a.at[:, dst].set(a[:, src]), c.kv)
            out.append(type(c)(kv, c.ssm))
        else:
            out.append(c)
    return tuple(out)


def snapshot_ssm(caches, row: int) -> tuple:
    """Value snapshot of one slot's SSM state across every SSM layer
    (``()`` placeholders for attention layers)."""
    return tuple(ssm_mod.ssm_state_slice(c.ssm, row) if _is_ssm(c.ssm)
                 else () for c in caches)


def restore_ssm(caches, snap: tuple, row: int):
    """Write a :func:`snapshot_ssm` back into ``row``."""
    out = []
    for c, s in zip(caches, snap):
        if _is_ssm(c.ssm):
            out.append(type(c)(c.kv, ssm_mod.ssm_state_restore(c.ssm, s, row)))
        else:
            out.append(c)
    return tuple(out)


def zero_ssm(caches, row: int):
    """Reset one slot's SSM rows to the initial state (fresh admission
    into a recycled slot must not inherit the previous occupant's
    recurrent state)."""
    out = []
    for c in caches:
        if _is_ssm(c.ssm):
            out.append(type(c)(c.kv, ssm_mod.ssm_state_zero_row(c.ssm, row)))
        else:
            out.append(c)
    return tuple(out)


def has_ssm(caches) -> bool:
    return any(_is_ssm(c.ssm) for c in caches)


def merge_prefill(caches, dense_caches, page_ids: List[int], slot: int,
                  page_size: int):
    """Scatter a one-shot (batch=1) dense prefill into the pool.

    ``dense_caches`` come from ``api.prefill_fn`` — KV (n_periods, 1,
    max_ctx, n_kv, hd), SSM (n_periods, 1, ...).  KV reshapes into the
    ``len(page_ids)`` pages the slot owns; SSM rows write at ``slot``."""
    ids = jnp.asarray(page_ids, jnp.int32)
    n = len(page_ids)
    out = []
    for c, d in zip(caches, dense_caches):
        if isinstance(c.kv, KVCache):
            def put(pages, dense):
                arr = dense[:, 0]                      # (n_periods, S, ...)
                need = n * page_size
                S = arr.shape[1]
                if need > S:
                    pad = [(0, 0)] * arr.ndim
                    pad[1] = (0, need - S)
                    arr = jnp.pad(arr, pad)
                chunk = arr[:, :need].reshape(
                    arr.shape[0], n, page_size, *arr.shape[2:])
                return pages.at[:, ids].set(chunk.astype(pages.dtype))
            kv = jax.tree.map(put, c.kv, d.kv)
            out.append(type(c)(kv, c.ssm))
        elif _is_ssm(c.ssm):
            st = jax.tree.map(
                lambda big, small: big.at[:, slot].set(
                    small[:, 0].astype(big.dtype)), c.ssm, d.ssm)
            out.append(type(c)(c.kv, st))
        else:
            out.append(c)
    return tuple(out)


def state_bytes(caches) -> Tuple[int, int]:
    """(bytes per physical page across all attn layers, SSM bytes per
    slot row across all SSM layers) — the pool's accounting constants."""
    page_b = 0
    ssm_b = 0
    for c in caches:
        if isinstance(c.kv, KVCache):
            for a in c.kv:
                # (n_periods, P, page_size, n_kv, hd): per page = all but P
                page_b += int(a.shape[0] * np.prod(a.shape[2:])) * a.dtype.itemsize
        if _is_ssm(c.ssm):
            for a in c.ssm:
                # (n_periods, B, ...): per row = all but B
                ssm_b += int(a.shape[0] * np.prod(a.shape[2:])) * a.dtype.itemsize
    return page_b, ssm_b
