from .engine import Engine, ServeConfig, RequestState
