from .engine import Engine, QueueFullError, RequestState, ServeConfig
from .scheduler import (Scheduler, SchedulerConfig, ServingMetrics, Ticket,
                        percentiles)
from .statepool import (PoolExhausted, PreemptedState, PrefixEntry,
                        StatePool, hash_chain)
from .traffic import (TrafficConfig, TrafficRequest, make_traffic,
                      run_closed_loop, to_sim_requests)
