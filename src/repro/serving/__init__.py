from .engine import Engine, ServeConfig, RequestState
from .scheduler import (Scheduler, SchedulerConfig, ServingMetrics, Ticket,
                        percentiles)
from .traffic import (TrafficConfig, TrafficRequest, make_traffic,
                      run_closed_loop, to_sim_requests)
