"""Layer-stepped serving engine with QoS token buffering (Algorithm 2).

Continuous-batching decode engine for LM-family models.  Each forward
iteration advances every active request by one token, executing the
network at MoE-boundary granularity so the engine can apply the paper's
token buffering exactly where Algorithm 2 specifies: *after* a layer's
gate is computed and *before* its experts execute.  A deferred request
keeps its post-attention hidden state (the carried residual stream) and
sub-layer progress and resumes from the same MoE boundary in a later
iteration — outputs are bit-identical to an undeferred run (asserted by
tests); only latency changes.

Two execution paths share one set of per-layer entry points
(``transformer.decode_*``), so they are bit-identical by construction:

* **fused** (default) — everything between MoE boundaries runs as one
  donated-buffer jitted mega-step (``repro.serving.megastep``): a
  steady-state decode iteration is ``k + 1`` compiled dispatches with
  at most **one host sync per MoE boundary** (a single
  ``device_get((counts, indices))`` feeding deferral, the workload
  trace, and the LoadTracker EMA) plus one logits fetch for sampling —
  counted in ``stats["host_syncs"]`` and pinned by tests;
* **legacy** (``ServeConfig(fused=False)``, and the automatic fallback
  under a distributed mesh) — the original eager per-layer Python loop.

Each MoE layer is **routed exactly once per iteration** (the pipeline's
route stage, ``repro.core.gating``): the same :class:`Routing` drives
the deferral decision, the paired-load trace, *and* the expert
execution (threaded into ``moe_block(routing=...)``), so the gate never
runs twice.  Per-layer :class:`~repro.core.trajectory.LoadTracker`
EMAs feed the observed expert counts back into the scheduler; with
``ExecutionSpec.schedule == "dynamic"`` each layer executes along the
EMA-built paired-load trajectory — in the fused path the trajectory
enters the compiled segment as a traced ``(E,)`` order array, so
re-planning every iteration never retraces.

Admission comes in two flavors: the legacy one-shot ``submit`` (full
prompt prefilled at batch=1 and merged into the batched cache slots) and
**chunked prefill** (``submit_chunked`` — no compute at admission; each
iteration's prefill-chunk stage appends up to ``chunk_tokens`` prompt
tokens per prefilling slot in one batched pass piggybacked on the decode
batch, so long prompts never block an iteration — the continuous-batching
scheduler in ``repro.serving.scheduler`` drives this path).  The
per-iteration expert token counts (decode route stage *and* prefill
chunks, tagged ``phase``) feed the paired-load policy and the deferral
decisions, and are exported for the chiplet simulator to replay (the JAX
engine and the cycle-level sim share one workload trace format — see
docs/trace-format.md).

Sequence state lives in the **paged state pool**
(``repro.serving.statepool``): attention KV in fixed-size physical
pages indexed per slot through one host page table (pushed to the
device once per iteration, traced — never retraces), Mamba2 state dense
per slot with by-value snapshots.  The pool underpins **prefix
caching** (content-hashed prompt prefixes admit with near-zero compute,
``ServeConfig.prefix_cache``) and **preemption**
(:meth:`Engine.preempt` / :meth:`Engine.restore` — bit-identical
eviction and resumption, driven by the scheduler under queue pressure).

Every trace record also carries ``modeled_s`` — the closed-form
chiplet-array seconds of that layer's observed expert flow
(``autotune.ServingCostModel``); their per-iteration sum is surfaced as
``last_step_modeled_s``, which the scheduler's modeled clock integrates
into machine-independent TTFT/TPOT seconds (see docs/benchmarks.md and
the ``sim.modes.replay_trace`` referee).
"""
from __future__ import annotations

import itertools
import warnings
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import autotune, gating, trajectory
from repro.core.policies import TokenBufferPolicy, paired_load_order
from repro.models import api, transformer
from repro.serving import megastep, statepool

_ALIAS_WARNED: set = set()


def _warn_alias(old: str, new: str) -> None:
    """One-shot DeprecationWarning per legacy ServeConfig alias."""
    if old in _ALIAS_WARNED:
        return
    _ALIAS_WARNED.add(old)
    warnings.warn(f"ServeConfig.{old} is deprecated; use {new} "
                  f"(see README migration table)", DeprecationWarning,
                  stacklevel=4)


@dataclass
class ServeConfig:
    max_batch: int = 8
    max_ctx: int = 256
    buffering_slack: float = 0.0
    theta_min: int = 2
    n_threshold: Optional[int] = None   # default derived from slack
    chunk_tokens: int = 16              # prefill chunk size (submit_chunked)
    # paged state pool (repro.serving.statepool): attention KV lives in
    # fixed-size physical pages indexed per slot through a host page
    # table; Mamba2 state stays dense per slot and snapshots by value.
    # pool_pages=None sizes the pool at twice the slot capacity, the
    # headroom prefix entries and preemption handles live in.
    page_size: int = 8
    pool_pages: Optional[int] = None
    # prefix caching: chunked-prefill state is content-hashed by the
    # prompt-prefix chain; a later request sharing a cached prefix
    # admits with the pages attached and only computes the suffix.
    # Off by default — it changes stats["prefill_tokens"] accounting.
    prefix_cache: bool = False
    max_prefix_entries: int = 64
    # preemption: when the scheduler's admission queue is deeper than
    # this bound and no slot is free, one restorable request is evicted
    # to the pool per step (None = never preempt)
    preempt_queue_depth: Optional[int] = None
    # Serving must be batching-invariant: a request's tokens may not
    # depend on who shares the batch.  Capacity dispatch drops tokens
    # past C = ceil(T*k/E * capacity_factor) per expert, and *which*
    # tokens overflow depends on the other rows — so by default the
    # engine raises the capacity factor to the drop-free bound (C = T*k).
    # Set False for the paper-faithful finite-buffer EP semantics.
    drop_free: bool = True
    # fused mega-step iteration (repro.serving.megastep): one compiled
    # segment per MoE-boundary span, at most one host sync per boundary.
    # False keeps the eager per-layer loop (bit-identical, much slower);
    # a distributed mesh falls back to the legacy loop automatically.
    fused: bool = True
    # single MoE execution configuration object (repro.core.strategy):
    # a spec, strategy name, or dict; replaces the old moe_impl/autotune
    # string knobs (kept below as deprecated aliases merged into it)
    spec: Optional[object] = None
    moe_impl: Optional[str] = None      # deprecated: use spec
    autotune: Optional[str] = None      # deprecated: use spec.autotune
    ema_decay: float = 0.8              # LoadTracker decay (dynamic sched)
    # EMA-hot expert weight tiering: pin each MoE layer's LoadTracker-
    # hottest experts resident on-package under this total byte budget
    # (split evenly across MoE layers); resident experts skip their DDR
    # stream in the modeled clock, the trace records (``resident``), and
    # the ``sim.modes.replay_trace`` referee.  Accounting-only — tokens
    # are bit-identical with tiering on or off.  0 disables the tier.
    resident_budget_mb: float = 0.0
    # hybrid two-tier placement: fast-tier expert count per MoE layer
    # when the spec uses the ``hybrid`` strategy (None = the registry
    # default, ``strategy.default_hot`` — top quartile).  The engine
    # repartitions per iteration off each layer's LoadTracker EMA and
    # records the partition in the trace (``hot`` ids, like
    # ``resident``); on homogeneous hardware the partition is
    # placement-only and tokens are bit-identical either way.
    hot_experts: Optional[int] = None
    temperature: float = 0.0            # 0 = greedy
    seed: int = 0

    def __post_init__(self):
        from dataclasses import replace
        from repro.core.strategy import ExecutionSpec
        if self.moe_impl is not None:
            _warn_alias("moe_impl",
                        'ServeConfig.spec=ExecutionSpec(strategy=...)')
        if self.autotune is not None:
            _warn_alias("autotune", "ExecutionSpec.autotune")
        base = self.spec if self.spec is not None else (self.moe_impl
                                                        or "capacity")
        sp = ExecutionSpec.coerce(base, default="capacity")
        if self.autotune is not None:
            sp = replace(sp, autotune=self.autotune)
        elif sp.autotune is None:
            sp = replace(sp, autotune="analytic")
        self.spec = sp.validate()
        if self.page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {self.page_size}")
        if self.preempt_queue_depth is not None \
                and self.preempt_queue_depth < 0:
            raise ValueError("preempt_queue_depth must be >= 0 (or None "
                             "to disable preemption)")
        if self.resident_budget_mb < 0:
            raise ValueError("resident_budget_mb must be >= 0 "
                             f"(got {self.resident_budget_mb})")


@dataclass
class RequestState:
    rid: str
    slot: int
    prompt_len: int
    max_new: int
    generated: List[int] = field(default_factory=list)
    progress: int = 0                   # sub-layer pointer: 2*layer (+1 = moe pending)
    done: bool = False
    deferred_iterations: int = 0
    # chunked-prefill lifecycle: "prefill" rows consume chunk_tokens
    # prompt tokens per iteration until the prompt is exhausted, then
    # join the decode batch ("decode") with their first sampled token
    phase: str = "decode"
    prompt: List[int] = field(default_factory=list)   # pending prompt tokens
    prefill_pos: int = 0                              # tokens already cached
    # prompt-prefix hash chain (statepool.hash_chain), computed once at
    # chunked admission when ServeConfig.prefix_cache is on
    prefix_keys: List[bytes] = field(default_factory=list)
    preemptions: int = 0                # times evicted to the state pool


class QueueFullError(RuntimeError):
    """No free engine slot.  A RuntimeError subclass so pre-existing
    ``except RuntimeError`` callers keep working; the continuous-batching
    scheduler catches this *type* to requeue instead of crashing."""


# deferral disabled when the activation threshold is effectively inf
_DEFER_OFF = 1 << 29


class Engine:
    def __init__(self, params, cfg: ModelConfig, scfg: ServeConfig):
        assert not cfg.is_encoder_decoder, "engine serves LM-family models"
        self.params = params
        if scfg.drop_free and cfg.moe is not None \
                and cfg.moe.capacity_factor < cfg.moe.num_experts:
            import dataclasses
            cfg = cfg.replace(moe=dataclasses.replace(
                cfg.moe, capacity_factor=float(cfg.moe.num_experts)))
        self.cfg = cfg
        self.scfg = scfg
        self.p, self.plan = transformer.cached_period_plan(cfg)
        self.L = cfg.num_layers
        pages_per_slot = -(-scfg.max_ctx // scfg.page_size)
        num_pages = (scfg.pool_pages if scfg.pool_pages is not None
                     else 2 * scfg.max_batch * pages_per_slot)
        self.caches = transformer.init_paged_caches(
            cfg, scfg.max_batch, num_pages, scfg.page_size)
        page_b, ssm_b = statepool.state_bytes(self.caches)
        # host-side page/refcount/prefix bookkeeping; device arrays stay
        # owned by the engine (self.caches), the pool tells it what to do
        self.pool = statepool.StatePool(
            max_batch=scfg.max_batch, max_ctx=scfg.max_ctx,
            page_size=scfg.page_size, num_pages=num_pages,
            max_prefix_entries=scfg.max_prefix_entries,
            bytes_per_page=page_b, ssm_bytes_per_row=ssm_b)
        self._has_ssm = statepool.has_ssm(self.caches)
        self._table_dev = jnp.asarray(self.pool.table)
        # host-side cache lengths: mutated in place (no device round-trip
        # per finished token), converted to a device array at call sites
        self.cache_len = np.zeros((scfg.max_batch,), np.int32)
        self.requests: Dict[str, RequestState] = {}
        # O(1) slot recycling: popleft to assign, append to recycle
        # (the old list.pop(0) was O(max_batch) per admission)
        self.free_slots = deque(range(scfg.max_batch))
        self.policy = TokenBufferPolicy.from_slack(scfg.buffering_slack,
                                                   theta_min=scfg.theta_min)
        if scfg.n_threshold is not None:
            self.policy.n_threshold = scfg.n_threshold
        self._x = jnp.zeros((scfg.max_batch, 1, cfg.d_model), jnp.dtype(cfg.dtype))
        self._rid = itertools.count()
        self._rng = np.random.default_rng(scfg.seed)
        self.iterations = 0
        self.stats = {"deferrals": 0, "expert_loads": 0, "expert_loads_saved": 0,
                      "iterations": 0, "tokens_emitted": 0,
                      "dynamic_schedules": 0,
                      "prefill_chunks": 0, "prefill_tokens": 0,
                      # device fetches on the fused path (boundary count
                      # fetches + logits fetches + prefill count fetches)
                      "host_syncs": 0,
                      "preemptions": 0, "restores": 0}
        # state-pool counters (pages in use / peak, cache hit/miss/evict,
        # prefill tokens saved, resident bytes) live in the same dict:
        # the pool mutates engine stats directly
        self.stats.update(self.pool.stats)
        self.pool.stats = self.stats
        self.trace: List[dict] = []     # per (iter, layer) expert counts
        # per-MoE-layer EMA of observed expert counts — the load vector
        # fed back into the dynamic trajectory scheduler each iteration
        self.load_trackers: Dict[int, trajectory.LoadTracker] = {}
        # latest EMA-built Schedule per layer (written at the boundary,
        # executed by the following segment / _apply_moe)
        self._layer_schedules: Dict[int, trajectory.Schedule] = {}
        self.dynamic_schedule = scfg.spec.schedule == "dynamic"
        # closed-form chiplet-array clock: modeled seconds per trace
        # record, integrated per iteration into last_step_modeled_s.
        # The spec's streamed weight dtype feeds the clock its expert
        # bytes-per-param so int8/fp8 runs model the smaller DDR stream.
        from repro.kernels import quant
        self.cost_model = (autotune.ServingCostModel.from_config(
            cfg, weight_bytes=quant.weight_bytes(scfg.spec.weight_dtype))
            if cfg.moe is not None else None)
        # EMA-hot expert weight tier: the resident_budget_mb bytes split
        # evenly over MoE layers pin this many experts per layer
        n_moe = sum(1 for l in range(self.L)
                    if self._layer_kind(l)[1] == "moe")
        self._n_resident = 0
        if scfg.resident_budget_mb > 0 and self.cost_model is not None \
                and n_moe:
            per_layer = int(scfg.resident_budget_mb * 2 ** 20) // n_moe
            self._n_resident = int(min(cfg.moe.num_experts,
                                       per_layer // self.cost_model.expert_bytes))
        self.stats["resident_weight_bytes"] = (
            self._n_resident * n_moe * self.cost_model.expert_bytes
            if self.cost_model is not None else 0)
        self.stats["ddr_bytes_saved"] = 0
        # hybrid two-tier placement: per-iteration hot/cold repartition
        # off the LoadTracker EMA, recorded per trace record (``hot``)
        self._n_hot = 0
        if cfg.moe is not None \
                and "hybrid" in scfg.spec.strategies_used():
            from repro.core.strategy import default_hot
            self._n_hot = int(scfg.hot_experts
                              if scfg.hot_experts is not None
                              else default_hot(cfg.moe.num_experts))
            self._n_hot = max(1, min(cfg.moe.num_experts, self._n_hot))
        self._last_hot: Dict[int, Tuple[int, ...]] = {}
        self.stats["hybrid_repartitions"] = 0
        self.last_step_modeled_s = 0.0
        self._iter_modeled_s = 0.0

    # ------------------------------------------------------------------
    # slot/param helpers
    # ------------------------------------------------------------------

    def _slot_params(self, layer: int):
        period_idx, slot = divmod(layer, self.p)
        return jax.tree.map(lambda a: a[period_idx], self.params["periods"][slot])

    def _layer_kind(self, layer: int) -> Tuple[str, str]:
        return self.plan[layer % self.p]

    # ------------------------------------------------------------------
    # admission (full-prompt prefill into a slot)
    # ------------------------------------------------------------------

    def _validate_request(self, prompt: List[int], max_new: int) -> None:
        if not prompt:
            raise ValueError("empty prompt")
        if max_new < 1:
            raise ValueError(f"max_new must be >= 1, got {max_new}")
        if len(prompt) + max_new > self.scfg.max_ctx:
            raise ValueError(
                f"request does not fit the context: len(prompt)={len(prompt)}"
                f" + max_new={max_new} > max_ctx={self.scfg.max_ctx} — "
                f"shorten the prompt or raise ServeConfig.max_ctx "
                f"(generation would be silently truncated)")

    def submit(self, prompt: List[int], max_new: int) -> str:
        self._validate_request(prompt, max_new)
        if not self.free_slots:
            raise QueueFullError("engine full — wait for completions")
        slot = self.free_slots.popleft()
        rid = f"req{next(self._rid)}"
        tokens = jnp.asarray(prompt, jnp.int32)[None]
        logits, caches1 = api.prefill_fn(self.params, {"tokens": tokens},
                                         self.cfg, self.scfg.max_ctx,
                                         spec=self.scfg.spec)
        # scatter the per-request dense caches into the slot's pool
        # pages (KV) and state row (SSM)
        self.pool.ensure(slot, len(prompt))
        self.caches = statepool.merge_prefill(
            self.caches, caches1, self.pool.slot_pages[slot], slot,
            self.scfg.page_size)
        self.cache_len[slot] = len(prompt)
        st = RequestState(rid=rid, slot=slot, prompt_len=len(prompt), max_new=max_new)
        first = self._sample(logits[0, -1])
        st.generated.append(int(first))
        self.requests[rid] = st
        return rid

    def submit_chunked(self, prompt: List[int], max_new: int) -> str:
        """Admit a request for chunked prefill: no compute happens here.

        The prompt is consumed ``chunk_tokens`` at a time by subsequent
        :meth:`step` calls (piggybacked on the decode batch), so
        admission never blocks an iteration; the first token is emitted
        by the step that caches the final prompt chunk.

        With ``ServeConfig.prefix_cache`` on, the longest cached prompt
        prefix (content-hashed chain, see repro.serving.statepool) is
        attached instead of recomputed: full pages share by refcount,
        the partial tail page copies, the SSM snapshot restores by
        value, and prefill resumes at the cached length — bit-identical
        to the cold run because per-token prefill outputs are
        chunk-partition-invariant under ``drop_free``."""
        self._validate_request(prompt, max_new)
        if not self.free_slots:
            raise QueueFullError("engine full — wait for completions")
        slot = self.free_slots.popleft()
        rid = f"req{next(self._rid)}"
        self.cache_len[slot] = 0
        st = RequestState(rid=rid, slot=slot, prompt_len=len(prompt),
                          max_new=max_new, phase="prefill",
                          prompt=list(prompt))
        hit = None
        if self.scfg.prefix_cache:
            st.prefix_keys = statepool.hash_chain(prompt)
            # at least one prompt token must run so first-token logits
            # exist — cap the usable prefix at len(prompt) - 1
            hit = self.pool.lookup_prefix(st.prefix_keys, len(prompt) - 1)
            if hit is not None:
                try:
                    copy = self.pool.attach_prefix(hit, slot)
                except statepool.PoolExhausted:
                    hit = None
            if hit is not None:
                if copy is not None:
                    self.caches = statepool.copy_page(self.caches, *copy)
                if hit.ssm != ():
                    self.caches = statepool.restore_ssm(self.caches,
                                                        hit.ssm, slot)
                self.cache_len[slot] = hit.length
                st.prefill_pos = hit.length
                self._record_event("cache_hit", rid=rid, slot=slot,
                                   cached_tokens=hit.length)
            else:
                self.stats["cache_misses"] += 1
        if hit is None and self._has_ssm:
            # a recycled slot must not leak the previous occupant's
            # recurrent state into a fresh prompt
            self.caches = statepool.zero_ssm(self.caches, slot)
        self.requests[rid] = st
        return rid

    def _sample(self, logits) -> int:
        lf = np.asarray(logits, np.float32)
        if self.scfg.temperature <= 0:
            return int(lf.argmax())
        p = np.exp((lf - lf.max()) / self.scfg.temperature)
        p /= p.sum()
        return int(self._rng.choice(len(p), p=p))

    # ------------------------------------------------------------------
    # one forward iteration (all active requests advance <= 1 token)
    # ------------------------------------------------------------------

    def active(self) -> List[RequestState]:
        return [r for r in self.requests.values() if not r.done]

    def prefilling(self) -> List[RequestState]:
        return [r for r in self.requests.values()
                if not r.done and r.phase == "prefill"]

    def _resident_for(self, layer: int) -> List[int]:
        """The layer's EMA-hot resident expert set: the ``_n_resident``
        hottest experts by LoadTracker EMA, ties broken by expert id —
        deterministic even before any traffic has been observed."""
        tracker = self.load_trackers.get(layer)
        if tracker is None or tracker.steps == 0:
            return list(range(self._n_resident))
        ema = np.asarray(tracker.ema, np.float64)
        hot = sorted(range(len(ema)), key=lambda e: (-ema[e], e))
        return sorted(hot[:self._n_resident])

    def _hot_for(self, layer: int) -> List[int]:
        """The layer's hybrid fast-tier expert set: the ``_n_hot``
        hottest experts by LoadTracker EMA (ties to the lower id) —
        identity prefix before any traffic, like ``_resident_for``."""
        tracker = self.load_trackers.get(layer)
        if tracker is None or tracker.steps == 0:
            return list(range(self._n_hot))
        ema = np.asarray(tracker.ema, np.float64)
        hot = sorted(range(len(ema)), key=lambda e: (-ema[e], e))
        return sorted(hot[:self._n_hot])

    def _record(self, rec: dict) -> None:
        """Append one workload-trace record, stamped with its modeled
        chiplet-array seconds (the per-iteration sum becomes
        ``last_step_modeled_s`` — the scheduler's modeled clock).

        With the EMA-hot weight tier on, the record also carries the
        layer's ``resident`` expert ids; resident experts that would
        have streamed this record skip their DDR term in the modeled
        clock and accrue ``stats["ddr_bytes_saved"]``.  With the
        ``hybrid`` strategy, it carries the fast-tier ``hot`` ids —
        the dynamic EMA repartition the two-tier replay referee
        (``sim.modes.replay_trace``) and the modeled clock price."""
        resident_n = 0
        if self._n_resident and "layer" in rec:
            resident = self._resident_for(rec["layer"])
            rec["resident"] = resident
            counts = rec["counts"]
            if rec["schedule"] == "dynamic":
                # a dynamic trajectory already skips idle experts: only
                # resident experts that routed tokens save a stream
                resident_n = sum(1 for e in resident if counts[int(e)] > 0)
            else:
                resident_n = len(resident)  # static plan loads every expert
            self.stats["ddr_bytes_saved"] += (resident_n
                                              * self.cost_model.expert_bytes)
        hot = None
        if self._n_hot and "layer" in rec:
            hot = self._hot_for(rec["layer"])
            rec["hot"] = hot
            prev = self._last_hot.get(rec["layer"])
            if prev is not None and prev != tuple(hot):
                self.stats["hybrid_repartitions"] += 1
            self._last_hot[rec["layer"]] = tuple(hot)
        if self.cost_model is not None:
            rec["modeled_s"] = self.cost_model.layer_s(
                rec["counts"], dynamic=rec["schedule"] == "dynamic",
                resident=resident_n, hot=hot)
            self._iter_modeled_s += rec["modeled_s"]
        self.trace.append(rec)

    def _record_event(self, event: str, **fields) -> None:
        """Append one *event* trace record (``cache_hit`` / ``preempt``
        / ``restore``).  Event records carry no ``counts`` and no
        modeled seconds — consumers that aggregate expert flow skip
        them (see docs/trace-format.md)."""
        self.trace.append({"iter": self.iterations, "event": event,
                           **fields})

    def _ensure_pages(self) -> None:
        """Host-side page allocation covering every KV write the coming
        iteration performs (the page table is read-only inside the
        jitted step).  A decode row writes one position; a prefill row
        writes its chunk, plus one more when the prompt completes (the
        row joins the decode batch in the same iteration)."""
        K = max(1, self.scfg.chunk_tokens)
        for r in self.active():
            if r.phase == "prefill":
                k_r = min(K, len(r.prompt) - r.prefill_pos)
                length = int(self.cache_len[r.slot]) + k_r
                if r.prefill_pos + k_r >= len(r.prompt):
                    length += 1
            else:
                length = int(self.cache_len[r.slot]) + 1
            self.pool.ensure(r.slot, min(length, self.scfg.max_ctx))
        self._table_dev = jnp.asarray(self.pool.table)

    def _register_prefix(self, r: RequestState) -> None:
        """Cache the slot's state at this chunk boundary under the
        prompt-prefix content hash.  Full pages are shared by refcount;
        the pool returns a (src, dst) plan when the partial tail page
        needs its own copy.  Skipped quietly when the pool cannot spare
        a tail page even after LRU eviction."""
        P = r.prefill_pos
        snap = (statepool.snapshot_ssm(self.caches, r.slot)
                if self._has_ssm else ())
        try:
            copy = self.pool.register_prefix(r.prefix_keys[P - 1], P,
                                             r.slot, ssm=snap)
        except statepool.PoolExhausted:
            return
        if copy is not None:
            self.caches = statepool.copy_page(self.caches, *copy)

    def _prefill_chunk_step(self, fused: bool = False) -> List[Tuple[str, int]]:
        """Advance every prefilling request by one prompt chunk.

        One batched ``prefill_chunk`` call covers all prefilling slots
        (decode/idle slots ride along fully masked, bit-untouched);
        per-layer expert counts from the chunk's gate pass feed the
        workload trace and the LoadTracker EMAs exactly like the decode
        path's route stage.  Requests whose prompt completes sample
        their first token from the last valid chunk position — the
        emission the scheduler timestamps as TTFT."""
        pre = self.prefilling()
        if not pre:
            return []
        scfg = self.scfg
        B, K = scfg.max_batch, max(1, scfg.chunk_tokens)
        tokens = np.zeros((B, K), np.int32)
        mask = np.zeros((B, K), bool)
        took: Dict[str, int] = {}
        for r in pre:
            k_r = min(K, len(r.prompt) - r.prefill_pos)
            tokens[r.slot, :k_r] = r.prompt[r.prefill_pos:r.prefill_pos + k_r]
            mask[r.slot, :k_r] = True
            took[r.rid] = k_r
        if fused:
            ms = megastep.get_megastep(self.cfg, self.scfg)
            hid, self.caches, counts = ms.prefill(
                self.params, tokens, self.caches,
                jnp.asarray(self.cache_len), self._table_dev,
                jnp.asarray(mask))
            self.stats["host_syncs"] += 1       # the counts fetch below
        else:
            hid, self.caches, counts = api.prefill_chunk_fn(
                self.params, jnp.asarray(tokens), self.caches,
                jnp.asarray(self.cache_len), self.cfg, spec=scfg.spec,
                token_mask=jnp.asarray(mask), return_hidden=True,
                page_table=self._table_dev)
        counts = np.asarray(counts, np.int64)
        for layer in range(self.L):
            if self._layer_kind(layer)[1] != "moe":
                continue
            cnt = counts[layer // self.p, layer % self.p]
            tracker = self.load_trackers.setdefault(
                layer, trajectory.LoadTracker(self.cfg.moe.num_experts,
                                              decay=scfg.ema_decay))
            tracker.update(cnt)
            self._record({
                "iter": self.iterations, "layer": layer, "phase": "prefill",
                "counts": cnt.copy(), "order": paired_load_order(cnt),
                "schedule": "dynamic" if self.dynamic_schedule else "static"})
            self.stats["expert_loads"] += int((cnt > 0).sum())

        out: List[Tuple[str, int]] = []
        head = self.params.get("lm_head")
        head = head if head is not None else self.params["embed"].T
        for r in pre:
            k_r = took[r.rid]
            self.cache_len[r.slot] += k_r
            r.prefill_pos += k_r
            self.stats["prefill_tokens"] += k_r
            if scfg.prefix_cache and r.prefix_keys:
                self._register_prefix(r)
            if r.prefill_pos < len(r.prompt):
                continue
            # prompt fully cached: unembed just this row's final chunk
            # position, emit the first token, and join decode
            first = self._sample(hid[r.slot, k_r - 1] @ head)
            r.generated.append(int(first))
            r.phase = "decode"
            r.progress = 0
            r.prompt = []
            out.append((r.rid, int(first)))
            self.stats["tokens_emitted"] += 1
            if len(r.generated) >= r.max_new:
                r.done = True
                self.free_slots.append(r.slot)
                self.pool.release_slot(r.slot)
                self.policy.drop(r.rid)
        self.stats["prefill_chunks"] += len(pre)
        return out

    def step(self) -> List[Tuple[str, int]]:
        self.last_step_modeled_s = 0.0
        if not self.active():
            return []
        self._iter_modeled_s = 0.0
        # allocate pages for this iteration's KV writes and push the
        # table once; it enters every jitted segment as a traced array
        self._ensure_pages()
        from repro.parallel import meshctx
        if self.scfg.fused and meshctx.get_mesh() is None:
            out = self._step_fused()
        else:
            out = self._step_legacy()
        self.last_step_modeled_s = self._iter_modeled_s
        return out

    # ------------------------------------------------------------------
    # fused mega-step iteration (repro.serving.megastep)
    # ------------------------------------------------------------------

    def _start_masks(self, act):
        """Fresh-token vector + start mask for rows beginning a pass."""
        B = self.scfg.max_batch
        token_vec = np.zeros((B,), np.int32)
        start_mask = np.zeros((B,), bool)
        for r in act:
            if r.progress == 0:
                token_vec[r.slot] = r.generated[-1]
                start_mask[r.slot] = True
        return token_vec, start_mask

    def _step_fused(self) -> List[Tuple[str, int]]:
        self.iterations += 1
        self.stats["iterations"] += 1
        out = self._prefill_chunk_step(fused=True)
        act = [r for r in self.active() if r.phase == "decode"]
        if not act:
            return out

        ms = megastep.get_megastep(self.cfg, self.scfg)
        token_vec, start_mask = self._start_masks(act)
        cl = jnp.asarray(self.cache_len)
        bnds = ms.boundaries

        if not bnds:
            self._x, self.caches, logits = ms.seg_only(
                self.params, self._x, self.caches, cl, self._table_dev,
                token_vec, start_mask)
            for r in act:
                if start_mask[r.slot]:
                    r.progress = 2 * self.L
            return self._finish(act, logits, out, fetch=True)

        # segment 0: embed merge + layers [0, b0) + mixer(b0) + route(b0)
        b0 = bnds[0]
        for r in act:
            if r.progress == 0:
                r.progress = 2 * b0 + 1
        run_ffn = [r for r in act if not r.done and r.progress == 2 * b0 + 1]
        self._x, self.caches, h, routing, counts = ms.seg_first(
            self.params, self._x, self.caches, cl, self._table_dev,
            token_vec, start_mask, self._mask([r.slot for r in run_ffn]))
        kept, order = self._boundary_fused(b0, run_ffn, routing, counts, ms)

        for j, b in enumerate(bnds[1:], start=1):
            exec_mask = self._mask([r.slot for r in kept])
            for r in kept:
                r.progress = 2 * b + 1
            run_ffn = [r for r in act
                       if not r.done and r.progress == 2 * b + 1]
            self._x, self.caches, h, routing, counts = ms.seg_mid[j - 1](
                self.params, self._x, self.caches, cl, self._table_dev,
                h, routing, order, exec_mask,
                self._mask([r.slot for r in run_ffn]))
            kept, order = self._boundary_fused(b, run_ffn, routing, counts,
                                               ms)

        self._x, self.caches, logits = ms.seg_last(
            self.params, self._x, self.caches, cl, self._table_dev,
            h, routing, order, self._mask([r.slot for r in kept]))
        for r in kept:
            r.progress = 2 * self.L
        return self._finish(act, logits, out, fetch=True)

    def _boundary_fused(self, layer, run_ffn, routing, counts_dev, ms):
        """Host work at one MoE boundary on the fused path: ONE device
        fetch (counts + routing indices) feeding deferral, the trace,
        and the EMA — then the shared boundary bookkeeping.  Returns
        (kept rows, trajectory order for the next segment)."""
        if not run_ffn:
            # nobody reaches this boundary: no fetch, no record, no EMA
            # (matches the legacy loop's `if not run_ffn: continue`)
            return [], ms.identity_order
        self.stats["host_syncs"] += 1
        if self.policy.n_threshold < _DEFER_OFF:
            counts_np, idx = jax.device_get((counts_dev, routing.indices))
        else:
            counts_np, idx = jax.device_get(counts_dev), None
        kept = self._boundary_host(layer, run_ffn,
                                   np.asarray(counts_np, np.int64), idx,
                                   routing)
        order = ms.identity_order
        if self.dynamic_schedule and kept:
            self.stats["dynamic_schedules"] += 1
            order = jnp.asarray(self._layer_schedules[layer].order, jnp.int32)
        return kept, order

    def _finish(self, act, logits, out, fetch=False):
        """Emit a token for every request that completed the pass, bump
        cache_len, reset progress.  ``fetch=True`` pulls the full logits
        batch in one transfer (the fused path's single sampling sync)."""
        cfg, scfg = self.cfg, self.scfg
        finish = [r for r in act if not r.done and r.progress == 2 * self.L]
        if not finish:
            return out
        if fetch:
            self.stats["host_syncs"] += 1
            logits = jax.device_get(logits)
        for r in finish:
            tok = self._sample(logits[r.slot, 0])
            r.generated.append(tok)
            out.append((r.rid, tok))
            self.stats["tokens_emitted"] += 1
            r.progress = 0
            self.cache_len[r.slot] += 1
            self.policy.on_forward_pass(r.rid)
            if len(r.generated) >= r.max_new or \
                    int(self.cache_len[r.slot]) >= scfg.max_ctx - 1:
                r.done = True
                self.free_slots.append(r.slot)
                self.pool.release_slot(r.slot)
                self.policy.drop(r.rid)
        return out

    # ------------------------------------------------------------------
    # legacy eager per-layer iteration (fused=False / distributed mesh)
    # ------------------------------------------------------------------

    def _step_legacy(self) -> List[Tuple[str, int]]:
        self.iterations += 1
        self.stats["iterations"] += 1

        # chunked-prefill stage: every prefilling slot consumes up to
        # chunk_tokens prompt tokens this iteration (one batched pass,
        # emitting first tokens for prompts that complete)
        out = self._prefill_chunk_step()
        act = [r for r in self.active() if r.phase == "decode"]
        if not act:
            return out

        # fresh-token embedding for requests starting a new pass
        token_vec, start_mask = self._start_masks(act)
        x = transformer.decode_embed_merge(self.params, self._x, token_vec,
                                           start_mask, self.cfg)
        for layer in range(self.L):
            _, ffn_kind = self._layer_kind(layer)
            run_attn = [r for r in act if not r.done and r.progress == 2 * layer]
            if run_attn:
                x = self._apply_mixer(x, layer, [r.slot for r in run_attn])
                for r in run_attn:
                    r.progress = 2 * layer + 1
            run_ffn = [r for r in act if not r.done and r.progress == 2 * layer + 1]
            if not run_ffn:
                continue
            if ffn_kind == "moe":
                # route ONCE: the same Routing drives deferral, the
                # trace, the EMA feedback, and the expert execution
                h, routing, _ = transformer.decode_route(self.params, x,
                                                         self.cfg, layer)
                run_ffn = self._defer_cold(routing, layer, run_ffn)
                if not run_ffn:
                    continue
                x = self._apply_moe(x, h, routing,
                                    [r.slot for r in run_ffn], layer)
            else:
                x = transformer.decode_ffn(self.params, x, self.cfg, layer,
                                           self._mask([r.slot for r in run_ffn]))
            for r in run_ffn:
                r.progress = 2 * (layer + 1)
        self._x = x

        # finishers: emit a token, bump cache_len, reset progress
        logits = transformer.decode_logits(self.params, x, self.cfg)
        return self._finish(act, logits, out)

    # ------------------------------------------------------------------
    # sub-layer executors (masked batched updates)
    # ------------------------------------------------------------------

    def _mask(self, slots: List[int]):
        m = np.zeros((self.scfg.max_batch,), bool)
        m[slots] = True
        return jnp.asarray(m)

    def _apply_mixer(self, x, layer, slots):
        x, self.caches = transformer.decode_mixer(
            self.params, x, self.caches, jnp.asarray(self.cache_len),
            self.cfg, layer, self._mask(slots),
            page_table=self._table_dev)
        return x

    def _slot_counts(self, routing, slots):
        """Expert counts restricted to the given slots
        (``gating.expert_token_counts`` with a row mask)."""
        return np.asarray(gating.expert_token_counts(
            routing, self._mask(slots)), np.int64)

    def _boundary_host(self, layer, run_ffn, counts, idx, routing):
        """Shared host bookkeeping at one MoE boundary (both paths):
        LoadTracker EMA update, workload-trace record (with the EMA
        trajectory under dynamic scheduling), and the Algorithm-2
        deferral sweep.  ``counts`` are this boundary's observed expert
        counts (np.int64), ``idx`` the per-row routed expert ids (None
        when deferral is off).  Returns the non-deferred rows."""
        tracker = self.load_trackers.setdefault(
            layer, trajectory.LoadTracker(self.cfg.moe.num_experts,
                                          decay=self.scfg.ema_decay))
        tracker.update(counts)
        rec = {"iter": self.iterations, "layer": layer, "phase": "decode",
               "counts": counts.copy(),
               "order": paired_load_order(counts),
               "schedule": "dynamic" if self.dynamic_schedule else "static"}
        if self.dynamic_schedule:
            # build the EMA schedule once; the expert execution that
            # follows (next segment / _apply_moe) runs along it.  Under
            # the hybrid strategy the plan carries the engine's fast-tier
            # width so the executed partition matches the trace's ``hot``
            plan = None
            if self._n_hot:
                plan = autotune.Plan(mode="hybrid", family="hybrid",
                                     micro_slices=1,
                                     hot_experts=self._n_hot)
            sched = tracker.schedule(plan=plan)
            self._layer_schedules[layer] = sched
            rec["trajectory"] = list(sched.order)
        self._record(rec)
        self.stats["expert_loads"] += int((counts > 0).sum())
        if self.policy.n_threshold >= _DEFER_OFF:
            return list(run_ffn)
        kept = []
        for r in run_ffn:
            acts = [int(e) for e in idx[r.slot]]
            if self.policy.should_defer(r.rid, acts, counts):
                self.stats["deferrals"] += 1
                r.deferred_iterations += 1
            else:
                kept.append(r)
        if len(kept) != len(run_ffn):
            counts2 = self._slot_counts(routing, [r.slot for r in kept])
            self.stats["expert_loads_saved"] += int((counts > 0).sum()
                                                    - (counts2 > 0).sum())
        return kept

    def _defer_cold(self, routing, layer, run_ffn):
        """Algorithm 2 at the MoE boundary (legacy eager path); returns
        the non-deferred set.  Also the *schedule* stage's observation
        point: the counts feed the layer's LoadTracker EMA and the
        exported workload trace."""
        counts = self._slot_counts(routing, [r.slot for r in run_ffn])
        idx = None
        if self.policy.n_threshold < _DEFER_OFF:
            idx = np.asarray(routing.indices)          # (B, k)
        return self._boundary_host(layer, run_ffn, counts, idx, routing)

    def _apply_moe(self, x, h, routing, slots, layer):
        """Dispatch + combine stages: execute the experts on the already
        routed activations, along the EMA-built trajectory when the
        spec's schedule is dynamic."""
        from repro.parallel import meshctx
        schedule = None
        if self.dynamic_schedule:
            schedule = self._layer_schedules[layer]   # built in _defer_cold
            self.stats["dynamic_schedules"] += 1
        # a precomputed Routing only matches the single-process layout;
        # distributed strategies re-route their local rows in shard_map
        routing_arg = routing if meshctx.get_mesh() is None else None
        return transformer.decode_moe_exec(
            self.params, x, h, routing_arg, self.cfg, layer,
            self._mask(slots), spec=self.scfg.spec, schedule=schedule)

    # ------------------------------------------------------------------
    # preemption: evict a request's state to the pool / restore it
    # ------------------------------------------------------------------

    def preempt(self, rid: str) -> statepool.PreemptedState:
        """Evict an active request's state to the pool, freeing its slot.

        Only requests at an iteration boundary (``progress == 0`` — not
        mid-pass with a deferred hidden state in the residual buffer)
        are restorable.  The page-table row detaches in O(1) (page
        ownership transfers to the handle, no data movement) and the
        SSM rows snapshot by value; :meth:`restore` resumes the request
        bit-identically in any free slot."""
        r = self.requests.get(rid)
        if r is None or r.done:
            raise ValueError(f"no active request {rid!r}")
        if r.progress != 0:
            raise ValueError(
                f"request {rid!r} is mid-pass (progress={r.progress}): its "
                f"deferred hidden state lives in the residual buffer and "
                f"cannot be evicted — pick a victim at progress == 0")
        snap = (statepool.snapshot_ssm(self.caches, r.slot)
                if self._has_ssm else ())
        handle = statepool.PreemptedState(
            request=r,
            page_ids=self.pool.detach_slot(r.slot, has_ssm=snap != ()),
            cache_len=int(self.cache_len[r.slot]), ssm=snap)
        del self.requests[rid]
        self.free_slots.append(r.slot)
        r.preemptions += 1
        self.stats["preemptions"] += 1
        self._record_event("preempt", rid=rid, slot=r.slot,
                           cache_len=handle.cache_len)
        return handle

    def restore(self, handle: statepool.PreemptedState) -> str:
        """Resume a preempted request in a free slot (same engine rid,
        so scheduler bookkeeping keyed on it stays valid)."""
        if not self.free_slots:
            raise QueueFullError("engine full — cannot restore preempted "
                                 "request; wait for completions")
        r = handle.request
        slot = self.free_slots.popleft()
        r.slot = slot
        self.pool.attach_pages(slot, handle.page_ids,
                               has_ssm=handle.ssm != ())
        self.cache_len[slot] = handle.cache_len
        if handle.ssm != ():
            self.caches = statepool.restore_ssm(self.caches, handle.ssm,
                                                slot)
        self.requests[r.rid] = r
        self.stats["restores"] += 1
        self._record_event("restore", rid=r.rid, slot=slot,
                           cache_len=handle.cache_len)
        return r.rid

    # ------------------------------------------------------------------

    def run(self, max_iterations: int = 10_000) -> Dict[str, List[int]]:
        for _ in range(max_iterations):
            if not self.active():
                break
            self.step()
        return {rid: r.generated for rid, r in self.requests.items()}
