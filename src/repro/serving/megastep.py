"""Fused jitted mega-steps for the serving engine's decode iteration.

The legacy engine walks the network layer by layer in Python, paying a
host round-trip per sub-layer — fine for exactness, hopeless for the
paper's fine-grained overlap story, where the host must not be the
bottleneck.  This module fuses everything *between* MoE boundaries into
one compiled segment, so a steady-state decode iteration is ``k + 1``
device dispatches (``k`` = number of MoE layers) with at most one host
sync per boundary:

* ``seg_first``  — fresh-token embed merge, the full layers before the
  first boundary ``b0``, the mixer at ``b0``, and the *route* stage at
  ``b0`` (routing + in-graph expert counts over the rows that will
  reach the boundary);
* ``seg_mid[j]`` — expert execution at boundary ``b_{j-1}`` (on the
  previous segment's routing, along the host-fed EMA trajectory when
  the schedule is dynamic), the span of full layers up to ``b_j``, the
  mixer at ``b_j``, and the route stage at ``b_j``;
* ``seg_last``   — expert execution at the final boundary, the trailing
  full layers, final norm and logits;
* ``seg_only``   — the no-MoE degenerate case (one segment end to end).

Between segments the host does exactly the work that genuinely needs
host values: the Algorithm-2 deferral decision, the workload-trace
record, and the LoadTracker EMA update — one
``jax.device_get((counts, indices))`` per boundary.  Every segment body
is built from the same ``transformer.decode_*`` entry points the legacy
eager loop calls, so fused and legacy iterations are bit-identical by
construction (asserted token-for-token and trace-for-trace in
``tests/test_megastep.py``).

Residual stream and caches are donated (``donate_argnums``) — the
engine rebinds both from each segment's outputs, so decode steps run
without per-iteration buffer growth.  Row selection is by traced
boolean masks and the dynamic trajectory enters as a traced ``(E,)``
order array, so steady-state decode (and deferral/finish churn) never
retraces: ``MegaStep.traces`` counts trace events and the test suite
pins it flat after warmup.
"""
from __future__ import annotations

from typing import List, Optional

import jax
import jax.numpy as jnp

from repro.core import trajectory
from repro.kernels import ops as kops
from repro.models import moe as moe_mod, transformer


class MegaStep:
    """Compiled decode segments + chunked-prefill step for one
    (model config, execution spec, engine geometry) cell.

    Instances are cached per configuration *and* per ambient kernel /
    sorted-dispatch flag (see :func:`get_megastep`): the flags are read
    at trace time inside ``ExecutionSpec.scope()``, so a segment traced
    with kernels on must never be reused with kernels off.
    """

    def __init__(self, cfg, spec, *, max_batch: int, max_ctx: int,
                 chunk_tokens: int):
        self.cfg = cfg
        self.spec = spec
        p, plan = transformer.cached_period_plan(cfg)
        L = cfg.num_layers
        self.boundaries: List[int] = [l for l in range(L)
                                      if plan[l % p][1] == "moe"]
        self.dynamic = spec is not None and spec.schedule == "dynamic"
        E = cfg.moe.num_experts if cfg.moe else 1
        # the static trajectory: canonical order (a no-op permutation);
        # dynamic segments overwrite it with the host-fed EMA order
        self.identity_order = jnp.arange(E, dtype=jnp.int32)
        # trace-event counter: each compiled-segment (re)trace bumps it
        # once (Python side effect in the traced body) — the recompile
        # guard in tests/test_megastep.py reads it
        self.traces = 0
        self._build()

    # ------------------------------------------------------------------

    def _schedule(self, order):
        """The per-boundary Schedule executed inside a segment: the
        host-fed EMA trajectory as a traced order (dynamic), or None
        (static — the untouched fast path)."""
        if not self.dynamic:
            return None
        return trajectory.Schedule(policy="dynamic", order=order)

    def _build(self):
        cfg, spec = self.cfg, self.spec
        L = cfg.num_layers
        bnds = self.boundaries

        # every segment takes the paged-KV ``table`` ((B, NP) int32) as a
        # traced array right after cache_len: page allocation happens on
        # the host between iterations, so table churn never retraces

        def prefill(params, tokens, caches, cache_len, table, token_mask):
            self.traces += 1
            return transformer.prefill_chunk(
                params, tokens, caches, cache_len, cfg, spec=spec,
                token_mask=token_mask, return_hidden=True, page_table=table)

        self.prefill = jax.jit(prefill, donate_argnums=(2,))

        if not bnds:
            def only(params, x, caches, cache_len, table, token_vec,
                     start_mask):
                self.traces += 1
                x = transformer.decode_embed_merge(params, x, token_vec,
                                                   start_mask, cfg)
                x, caches = transformer.decode_span(params, x, caches,
                                                    cache_len, cfg, 0, L,
                                                    start_mask,
                                                    page_table=table)
                return x, caches, transformer.decode_logits(params, x, cfg)

            self.seg_only = jax.jit(only, donate_argnums=(1, 2))
            self.seg_first = self.seg_mid = self.seg_last = None
            return

        b0 = bnds[0]

        def first(params, x, caches, cache_len, table, token_vec, start_mask,
                  count_mask):
            self.traces += 1
            x = transformer.decode_embed_merge(params, x, token_vec,
                                               start_mask, cfg)
            x, caches = transformer.decode_span(params, x, caches, cache_len,
                                                cfg, 0, b0, start_mask,
                                                page_table=table)
            x, caches = transformer.decode_mixer(params, x, caches, cache_len,
                                                 cfg, b0, start_mask,
                                                 page_table=table)
            h, routing, counts = transformer.decode_route(params, x, cfg, b0,
                                                          count_mask)
            return x, caches, h, routing, counts

        self.seg_first = jax.jit(first, donate_argnums=(1, 2))

        def make_mid(b_prev: int, b: int):
            def mid(params, x, caches, cache_len, table, h, routing, order,
                    exec_mask, count_mask):
                self.traces += 1
                x = transformer.decode_moe_exec(
                    params, x, h, routing, cfg, b_prev, exec_mask,
                    spec=spec, schedule=self._schedule(order))
                x, caches = transformer.decode_span(
                    params, x, caches, cache_len, cfg, b_prev + 1, b,
                    exec_mask, page_table=table)
                x, caches = transformer.decode_mixer(
                    params, x, caches, cache_len, cfg, b, exec_mask,
                    page_table=table)
                h, routing, counts = transformer.decode_route(params, x, cfg,
                                                              b, count_mask)
                return x, caches, h, routing, counts
            return jax.jit(mid, donate_argnums=(1, 2))

        self.seg_mid = [make_mid(bnds[j - 1], bnds[j])
                        for j in range(1, len(bnds))]

        b_tail = bnds[-1]

        def last(params, x, caches, cache_len, table, h, routing, order,
                 exec_mask):
            self.traces += 1
            x = transformer.decode_moe_exec(
                params, x, h, routing, cfg, b_tail, exec_mask,
                spec=spec, schedule=self._schedule(order))
            x, caches = transformer.decode_span(params, x, caches, cache_len,
                                                cfg, b_tail + 1, L, exec_mask,
                                                page_table=table)
            return x, caches, transformer.decode_logits(params, x, cfg)

        self.seg_last = jax.jit(last, donate_argnums=(1, 2))
        self.seg_only = None


_CACHE: dict = {}


def get_megastep(cfg, scfg) -> MegaStep:
    """The (cached) MegaStep for one engine configuration.

    Keyed on everything that changes the compiled segments: the model
    config, the execution spec, the engine geometry, and the *ambient*
    kernel / sorted-dispatch flags (contextvars read at trace time).
    Called once per engine iteration — a dict hit in the steady state.
    Unhashable configs fall back to an uncached instance.
    """
    try:
        key = (cfg, scfg.spec, scfg.max_batch, scfg.max_ctx,
               scfg.chunk_tokens, scfg.page_size, scfg.pool_pages,
               kops.kernels_enabled(),
               moe_mod.sorted_dispatch_enabled())
        hash(key)
    except TypeError:
        return MegaStep(cfg, scfg.spec, max_batch=scfg.max_batch,
                        max_ctx=scfg.max_ctx, chunk_tokens=scfg.chunk_tokens)
    ms = _CACHE.get(key)
    if ms is None:
        ms = _CACHE[key] = MegaStep(cfg, scfg.spec, max_batch=scfg.max_batch,
                                    max_ctx=scfg.max_ctx,
                                    chunk_tokens=scfg.chunk_tokens)
    return ms
