"""Continuous-batching admission scheduler + serving metrics.

The front end the paper's low-batch serving scenario actually needs:
requests arrive continuously (Poisson traffic, skewed lengths), wait in
a **bounded admission queue**, and are admitted into engine slots the
moment one frees — prefill happens in fixed-token chunks piggybacked on
the decode batch (``Engine.submit_chunked`` + the engine's per-iteration
prefill-chunk stage), so a long prompt never blocks an iteration and
admission is O(1).

Queue policies:

* ``fcfs`` — strict FIFO; arrival order is admission order, so no
  request can starve.
* ``spf``  — shortest-prompt-first (a cheap SJF proxy that improves mean
  TTFT under mixed lengths), with an **aging guard**: once the queue
  head has waited ``starvation_limit`` scheduler iterations it is
  admitted next regardless of length, bounding worst-case queue delay.

Per-request streaming emission: every generated token is surfaced
through :meth:`Scheduler.step`'s return value and the optional
``on_token`` callback the moment its iteration completes.

Metrics (clock units are whatever ``step(dt)`` advances — wall seconds
in the serve CLI, iterations in tests/benchmarks, keeping the committed
benchmark baselines machine-independent):

* **TTFT**        — arrival -> first emitted token,
* **TPOT**        — mean inter-token time after the first,
* **queue delay** — arrival -> slot admission,

aggregated into p50/p95/p99 by :class:`ServingMetrics`.

The primary clock is pluggable: ``clock=None`` (iteration-counted,
default), a callable like ``time.monotonic`` (wall seconds), or the
string ``"modeled"`` — each step then advances by the engine's
``last_step_modeled_s``, the closed-form chiplet-array seconds of the
iteration's observed expert flow (``autotune.ServingCostModel``), so
every latency metric is in machine-independent modeled seconds.
Independently of the primary clock, a **secondary modeled clock**
(``modeled_now``) always integrates the same quantity, and every
ticket carries modeled-time stamps — ``ServingMetrics`` therefore
always reports ``ttft_modeled`` / ``tpot_modeled`` /
``queue_delay_modeled`` / ``elapsed_modeled`` alongside the primary
metrics (see docs/benchmarks.md for how the serving benchmark gates on
these).
"""
from __future__ import annotations

import itertools
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, List, Optional, Tuple

import numpy as np

from .engine import Engine, QueueFullError


@dataclass
class SchedulerConfig:
    queue_capacity: int = 64
    policy: str = "fcfs"            # fcfs | spf (shortest-prompt-first)
    starvation_limit: int = 32      # spf aging: head admitted after N iters

    def __post_init__(self):
        if self.policy not in ("fcfs", "spf"):
            raise ValueError(f"unknown queue policy {self.policy!r} "
                             f"(want 'fcfs' or 'spf')")
        if self.queue_capacity < 1:
            raise ValueError("queue_capacity must be >= 1")


@dataclass
class Ticket:
    """One request's lifecycle through queue -> engine -> completion."""
    rid: str
    prompt: List[int]
    max_new: int
    arrival: float
    arrival_iter: int
    engine_rid: Optional[str] = None
    admitted_at: Optional[float] = None
    admitted_iter: Optional[int] = None
    first_token_at: Optional[float] = None
    finished_at: Optional[float] = None
    tokens: List[int] = field(default_factory=list)
    # the same lifecycle on the secondary modeled clock (chiplet-array
    # seconds integrated from the engine's per-iteration cost model)
    arrival_m: float = 0.0
    admitted_m: Optional[float] = None
    first_token_m: Optional[float] = None
    finished_m: Optional[float] = None

    @property
    def done(self) -> bool:
        return self.finished_at is not None


def percentiles(values, qs=(50, 95, 99)) -> Dict[str, float]:
    if not values:
        return {f"p{q}": float("nan") for q in qs}
    arr = np.asarray(values, np.float64)
    return {f"p{q}": float(np.percentile(arr, q)) for q in qs}


@dataclass
class ServingMetrics:
    """Aggregated per-request latency metrics in clock units."""
    ttft: Dict[str, float]
    tpot: Dict[str, float]
    queue_delay: Dict[str, float]
    completed: int
    rejected: int
    tokens_emitted: int
    elapsed: float
    iterations: int
    # secondary modeled clock (machine-independent chiplet-array
    # seconds) — always present when the engine has a cost model
    ttft_modeled: Dict[str, float] = field(default_factory=dict)
    tpot_modeled: Dict[str, float] = field(default_factory=dict)
    queue_delay_modeled: Dict[str, float] = field(default_factory=dict)
    elapsed_modeled: float = 0.0
    # state-pool activity (mirrored from Engine.stats; see
    # docs/statepool.md)
    preemptions: int = 0
    restores: int = 0
    cache_hits: int = 0
    cache_misses: int = 0
    prefill_tokens_saved: int = 0

    @property
    def throughput(self) -> float:
        return self.tokens_emitted / max(self.elapsed, 1e-12)

    @property
    def throughput_modeled(self) -> float:
        return self.tokens_emitted / max(self.elapsed_modeled, 1e-12)

    def to_dict(self) -> dict:
        return {
            "ttft": self.ttft, "tpot": self.tpot,
            "queue_delay": self.queue_delay,
            "completed": self.completed, "rejected": self.rejected,
            "tokens_emitted": self.tokens_emitted,
            "elapsed": self.elapsed, "iterations": self.iterations,
            "throughput": self.throughput,
            "ttft_modeled": self.ttft_modeled,
            "tpot_modeled": self.tpot_modeled,
            "queue_delay_modeled": self.queue_delay_modeled,
            "elapsed_modeled": self.elapsed_modeled,
            "throughput_modeled": self.throughput_modeled,
            "preemptions": self.preemptions, "restores": self.restores,
            "cache_hits": self.cache_hits,
            "cache_misses": self.cache_misses,
            "prefill_tokens_saved": self.prefill_tokens_saved,
        }


class Scheduler:
    """Bounded-queue continuous-batching front end over one Engine."""

    def __init__(self, engine: Engine, cfg: Optional[SchedulerConfig] = None,
                 on_token: Optional[Callable[[str, int], None]] = None,
                 clock: Optional[Callable[[], float]] = None):
        self.engine = engine
        self.cfg = cfg or SchedulerConfig()
        self.on_token = on_token
        # None -> iteration-counted metric clock (deterministic; each
        # step advances by dt).  A callable (e.g. time.monotonic) makes
        # every metric wall-clocked; "modeled" advances by the engine's
        # last_step_modeled_s (machine-independent modeled seconds).
        if isinstance(clock, str) and clock != "modeled":
            raise ValueError(f"unknown clock {clock!r} "
                             f"(want None, a callable, or 'modeled')")
        self.clock = clock
        self._t0 = clock() if callable(clock) else 0.0
        # secondary modeled clock: always integrates the engine's
        # per-iteration modeled seconds, whatever the primary clock
        self.modeled_now = 0.0
        self.queue: Deque[Ticket] = deque()
        self.tickets: Dict[str, Ticket] = {}        # by scheduler rid
        self._by_engine: Dict[str, Ticket] = {}     # engine rid -> ticket
        # preemption handles (Engine.preempt), restored oldest-first
        # into slots left over once the admission queue drains
        self._preempted: Deque = deque()
        self._rid = itertools.count()
        self.now = 0.0
        self.iteration = 0
        self.rejected = 0

    # ------------------------------------------------------------------
    # arrivals
    # ------------------------------------------------------------------

    def offer(self, prompt: List[int], max_new: int,
              arrival: Optional[float] = None) -> Optional[str]:
        """Enqueue a request; returns its rid, or None when the bounded
        queue is full (the caller sees backpressure, never an error from
        deep inside the engine).

        ``arrival`` is the request's true arrival timestamp when the
        caller knows it (the traffic loop only polls between engine
        steps, so stamping at offer time would silently exclude up to
        one iteration of queueing from TTFT/queue-delay); default: now.
        """
        if len(self.queue) >= self.cfg.queue_capacity:
            self.rejected += 1
            return None
        # surface bad requests at the door, before they occupy a slot
        self.engine._validate_request(list(prompt), max_new)
        t = Ticket(rid=f"t{next(self._rid)}", prompt=list(prompt),
                   max_new=max_new,
                   arrival=self.now if arrival is None else min(arrival,
                                                                self.now),
                   arrival_iter=self.iteration,
                   arrival_m=self.modeled_now)
        self.queue.append(t)
        self.tickets[t.rid] = t
        return t.rid

    def queue_depth(self) -> int:
        return len(self.queue)

    # ------------------------------------------------------------------
    # admission
    # ------------------------------------------------------------------

    def _pick(self) -> Ticket:
        if self.cfg.policy == "spf":
            head = self.queue[0]
            if self.iteration - head.arrival_iter < self.cfg.starvation_limit:
                # shortest prompt; FIFO among equals (stable argmin)
                best = min(range(len(self.queue)),
                           key=lambda i: (len(self.queue[i].prompt), i))
                t = self.queue[best]
                del self.queue[best]
                return t
            # aging guard: the head has waited long enough — FIFO pick
        return self.queue.popleft()

    def admit_ready(self) -> List[str]:
        """Fill free engine slots from the queue; returns admitted rids.

        Queued arrivals take freed slots first — that is what a
        preemption bought — and preempted requests are restored
        (oldest-first, bit-identically) into whatever slots remain once
        the queue drains.  A slot lost to a concurrent direct
        ``submit`` surfaces as :class:`QueueFullError`, which requeues
        the ticket instead of crashing the serving loop."""
        admitted = []
        while self.engine.free_slots and self.queue:
            t = self._pick()
            try:
                t.engine_rid = self.engine.submit_chunked(t.prompt,
                                                          t.max_new)
            except QueueFullError:
                self.queue.appendleft(t)
                break
            t.admitted_at = self.now
            t.admitted_iter = self.iteration
            t.admitted_m = self.modeled_now
            self._by_engine[t.engine_rid] = t
            admitted.append(t.rid)
        while self.engine.free_slots and self._preempted:
            self.engine.restore(self._preempted.popleft())
        return admitted

    def _maybe_preempt(self) -> None:
        """Queue-pressure preemption: when the admission queue is deeper
        than ``ServeConfig.preempt_queue_depth`` and no slot is free,
        evict one restorable victim per step to the state pool — the
        request with the most remaining work, at an iteration boundary,
        not already preempted twice (the cap prevents thrash)."""
        bound = self.engine.scfg.preempt_queue_depth
        if bound is None or len(self.queue) <= bound \
                or self.engine.free_slots:
            return
        victims = [r for r in self.engine.requests.values()
                   if not r.done and r.progress == 0 and r.preemptions < 2]
        if not victims:
            return
        v = max(victims,
                key=lambda r: (r.max_new - len(r.generated))
                + (len(r.prompt) - r.prefill_pos))
        self._preempted.append(self.engine.preempt(v.rid))

    # ------------------------------------------------------------------
    # the serving loop
    # ------------------------------------------------------------------

    def step(self, dt: float = 1.0) -> List[Tuple[str, int]]:
        """One scheduler iteration: admit, run one engine step, emit.

        ``dt`` advances the metric clock (wall seconds in real serving;
        the default 1.0 makes all latency metrics iteration-counted and
        fully deterministic).  Returns (rid, token) pairs in scheduler
        rids."""
        self.iteration += 1
        self._maybe_preempt()
        self.admit_ready()
        events = self.engine.step()
        adv = getattr(self.engine, "last_step_modeled_s", 0.0)
        self.modeled_now += adv
        if callable(self.clock):
            self.now = self.clock() - self._t0
        elif self.clock == "modeled":
            # fall back to dt for iterations the model cannot see (no
            # MoE work, e.g. a pure-attention span) so the clock — and
            # the traffic loop feeding it — always advances
            self.now += adv if adv > 0 else dt
        else:
            self.now += dt
        out: List[Tuple[str, int]] = []
        for erid, tok in events:
            t = self._by_engine.get(erid)
            if t is None:
                continue                      # directly-submitted request
            if t.first_token_at is None:
                t.first_token_at = self.now
                t.first_token_m = self.modeled_now
            t.tokens.append(tok)
            out.append((t.rid, tok))
            if self.on_token is not None:
                self.on_token(t.rid, tok)
        # prune finished tickets from the per-step scan (they stay in
        # self.tickets for outputs()/metrics()) so a long-running server
        # does O(active) work per iteration, not O(all-time requests)
        for erid, t in list(self._by_engine.items()):
            st = self.engine.requests.get(erid)
            if st is not None and st.done and not t.done:
                t.finished_at = self.now
                t.finished_m = self.modeled_now
                del self._by_engine[erid]
        return out

    def pending(self) -> int:
        """Requests not yet finished (queued + in flight)."""
        return len(self.queue) + sum(
            1 for t in self._by_engine.values() if not t.done)

    def drain(self, max_iterations: int = 100_000, dt: float = 1.0) -> None:
        """Run until every offered request completes."""
        for _ in range(max_iterations):
            if not self.pending():
                return
            self.step(dt)
        raise RuntimeError(f"drain did not converge within {max_iterations} "
                           f"iterations ({self.pending()} pending)")

    # ------------------------------------------------------------------
    # metrics
    # ------------------------------------------------------------------

    def outputs(self) -> Dict[str, List[int]]:
        return {t.rid: list(t.tokens) for t in self.tickets.values()
                if t.engine_rid is not None}

    def metrics(self) -> ServingMetrics:
        done = [t for t in self.tickets.values() if t.done]
        ttft = [t.first_token_at - t.arrival for t in done
                if t.first_token_at is not None]
        qdel = [t.admitted_at - t.arrival for t in done
                if t.admitted_at is not None]
        tpot = [(t.finished_at - t.first_token_at) / (len(t.tokens) - 1)
                for t in done
                if t.first_token_at is not None and len(t.tokens) > 1]
        ttft_m = [t.first_token_m - t.arrival_m for t in done
                  if t.first_token_m is not None]
        qdel_m = [t.admitted_m - t.arrival_m for t in done
                  if t.admitted_m is not None]
        tpot_m = [(t.finished_m - t.first_token_m) / (len(t.tokens) - 1)
                  for t in done
                  if t.first_token_m is not None and len(t.tokens) > 1]
        est = self.engine.stats
        return ServingMetrics(
            ttft=percentiles(ttft), tpot=percentiles(tpot),
            queue_delay=percentiles(qdel), completed=len(done),
            rejected=self.rejected,
            tokens_emitted=sum(len(t.tokens) for t in self.tickets.values()),
            elapsed=self.now, iterations=self.iteration,
            ttft_modeled=percentiles(ttft_m), tpot_modeled=percentiles(tpot_m),
            queue_delay_modeled=percentiles(qdel_m),
            elapsed_modeled=self.modeled_now,
            preemptions=int(est.get("preemptions", 0)),
            restores=int(est.get("restores", 0)),
            cache_hits=int(est.get("cache_hits", 0)),
            cache_misses=int(est.get("cache_misses", 0)),
            prefill_tokens_saved=int(est.get("prefill_tokens_saved", 0)))
