"""Poisson traffic generation for closed-loop serving.

Builds the request stream the paper's low-batch scenario assumes:
arrivals are a Poisson process (exponential inter-arrival gaps at
``rate`` requests per time unit), request sizes come from the same
mixed prefill/decode splitter the chiplet simulator uses
(``sim.workload.make_requests`` — Poisson-sized prompts around
``avg_prompt``), and each request carries a private Zipf *affinity*
over the vocabulary (``sim.workload.sample_expert_probs`` with the
request's affinity seed): its prompt tokens are drawn from a skewed,
request-specific slice of the vocab, which is what produces the
long-tail expert activation the dynamic trajectory scheduler feeds on.

Beyond the plain Poisson stream, ``TrafficConfig.mix`` composes
modifiers ("+"-separated): ``zipf_prefix`` prepends Zipf-shared system
prompts (the workload shape prefix caching feeds on — a few hot
prefixes dominate), and ``diurnal`` modulates the arrival rate with a
sinusoidal burst cycle (the queue-pressure shape that triggers
preemption).  The default ``"poisson"`` stream is byte-identical to
what this module generated before mixes existed.

The same :class:`TrafficRequest` list replays into the simulator via
``to_sim_requests`` — engine and chiplet sim consume one workload.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import List

import numpy as np

from repro.sim import workload as sim_workload


@dataclass
class TrafficConfig:
    num_requests: int = 32
    rate: float = 0.5                # Poisson arrivals per time unit
    avg_prompt: int = 12             # mean prompt length (Poisson-sized)
    min_prompt: int = 1
    max_prompt: int = 64
    min_new: int = 2
    max_new: int = 8                 # output lengths uniform in [min,max]
    zipf_s: float = 1.1              # per-request token-affinity skew
    vocab: int = 256
    num_chiplets: int = 4            # home-chiplet striping for the sim
    seed: int = 0
    # traffic mix: "poisson" plus "+"-separated modifiers —
    # "zipf_prefix" (Zipf-shared system prompts) and/or "diurnal"
    # (sinusoidal arrival-rate bursts), e.g. "poisson+zipf_prefix"
    mix: str = "poisson"
    num_prefixes: int = 4            # distinct shared system prompts
    prefix_len: int = 12             # tokens per shared prompt
    prefix_zipf_s: float = 1.3       # skew of prefix popularity
    burst_period: float = 16.0       # diurnal cycle (clock units)
    burst_amplitude: float = 0.8     # rate modulation depth in [0, 1)

    def __post_init__(self):
        unknown = set(self.mix.split("+")) - {"poisson", "zipf_prefix",
                                              "diurnal"}
        if unknown:
            raise ValueError(f"unknown traffic mix component(s) "
                             f"{sorted(unknown)} — want 'poisson', "
                             f"'zipf_prefix', 'diurnal' joined by '+'")


@dataclass
class TrafficRequest:
    rid: str
    arrival: float
    prompt: List[int] = field(default_factory=list)
    max_new: int = 1
    affinity_seed: int = 0
    home_chiplet: int = 0


def make_traffic(cfg: TrafficConfig) -> List[TrafficRequest]:
    """Deterministic request stream for one (config, seed)."""
    rng = np.random.default_rng(cfg.seed)
    # request-size / affinity structure from the simulator's splitter:
    # ask for enough token budget that >= num_requests fall out, then
    # keep exactly num_requests
    sized: List[sim_workload.Request] = []
    budget = cfg.num_requests * max(1, cfg.avg_prompt)
    attempt = 0
    # growing the budget only *extends* the splitter's request list (the
    # rng sequence is a pure function of the seed), so the stream is
    # stable under retries and distinct across seeds
    while len(sized) < cfg.num_requests:
        sized = sim_workload.make_requests(
            budget, cfg.num_chiplets, cfg.seed,
            avg_request_tokens=cfg.avg_prompt)
        budget *= 2
        attempt += 1
        if attempt > 16:
            raise RuntimeError("traffic splitter failed to produce "
                               f"{cfg.num_requests} requests")
    sized = sized[:cfg.num_requests]

    parts = set(cfg.mix.split("+"))
    prefixes: List[List[int]] = []
    prefix_probs = None
    if "zipf_prefix" in parts:
        # shared system prompts, deterministic per seed; popularity is
        # Zipf-skewed via the simulator's sampler (a hot head of reused
        # prefixes is the workload prefix caching feeds on).  At least
        # one private token must follow, so cap at max_prompt - 1.
        prng = np.random.default_rng(cfg.seed + 10_007)
        plen_shared = min(cfg.prefix_len, max(1, cfg.max_prompt - 1))
        for _ in range(cfg.num_prefixes):
            pprobs = sim_workload.sample_expert_probs(cfg.vocab, prng,
                                                      zipf_s=cfg.zipf_s)
            prefixes.append(prng.choice(cfg.vocab, size=plen_shared,
                                        p=pprobs).tolist())
        prefix_probs = sim_workload.sample_expert_probs(
            cfg.num_prefixes, prng, zipf_s=cfg.prefix_zipf_s)

    out: List[TrafficRequest] = []
    now = 0.0
    for i, req in enumerate(sized):
        rate = max(cfg.rate, 1e-9)
        if "diurnal" in parts:
            # sinusoidal rate modulation: bursts above the mean rate
            # alternate with troughs — the queue-pressure shape that
            # exercises the scheduler's preemption policy
            phase = np.sin(2.0 * np.pi * now / max(cfg.burst_period, 1e-9))
            rate = max(rate * (1.0 + cfg.burst_amplitude * phase), 1e-9)
        now += float(rng.exponential(1.0 / rate))
        plen = int(np.clip(req.num_tokens, cfg.min_prompt, cfg.max_prompt))
        # per-request Zipf affinity over the vocab: a private permutation
        # of Zipf-ranked probabilities, seeded by the request's affinity
        # seed (the simulator uses the identical construction over experts)
        arng = np.random.default_rng(req.affinity_seed)
        probs = sim_workload.sample_expert_probs(cfg.vocab, arng,
                                                 zipf_s=cfg.zipf_s)
        prompt = arng.choice(cfg.vocab, size=plen, p=probs).tolist()
        if prefixes:
            shared = prefixes[int(rng.choice(len(prefixes),
                                             p=prefix_probs))]
            keep = max(1, cfg.max_prompt - len(shared))
            prompt = shared + prompt[:keep]
        max_new = int(rng.integers(cfg.min_new, cfg.max_new + 1))
        out.append(TrafficRequest(rid=f"traffic{i}", arrival=now,
                                  prompt=[int(t) for t in prompt],
                                  max_new=max_new,
                                  affinity_seed=req.affinity_seed,
                                  home_chiplet=req.home_chiplet))
    return out


def to_sim_requests(traffic: List[TrafficRequest]
                    ) -> List[sim_workload.Request]:
    """The same stream as simulator Requests (conformance replay)."""
    return [sim_workload.Request(rid=t.rid, num_tokens=len(t.prompt),
                                 home_chiplet=t.home_chiplet,
                                 affinity_seed=t.affinity_seed)
            for t in traffic]


def run_closed_loop(scheduler, traffic: List[TrafficRequest], *,
                    dt: float = 1.0, max_iterations: int = 100_000) -> dict:
    """Feed a traffic stream through a Scheduler until it drains.

    Arrival times are interpreted on the scheduler's clock (iteration
    counts advancing by ``dt`` per step unless the scheduler was built
    with a wall clock or ``clock="modeled"``): every request whose
    arrival time has passed is offered before the next step.  Returns ``{"metrics": ServingMetrics,
    "outputs": {rid: tokens}, "dropped": [rid, ...]}`` — dropped
    requests hit the bounded queue.
    """
    todo = sorted(traffic, key=lambda t: (t.arrival, t.rid))
    i = 0
    dropped: List[str] = []
    offered: dict = {}
    iters = 0
    while True:
        while i < len(todo) and todo[i].arrival <= scheduler.now:
            rid = scheduler.offer(todo[i].prompt, todo[i].max_new,
                                  arrival=todo[i].arrival)
            if rid is None:
                dropped.append(todo[i].rid)
            else:
                offered[rid] = todo[i].rid
            i += 1
        if i >= len(todo) and not scheduler.pending():
            break
        if not scheduler.pending() and callable(scheduler.clock):
            # wall-clocked and idle before the next arrival: sleep the
            # gap out (bounded slices so the loop stays responsive)
            # instead of burning engine iterations — idle waits do not
            # count against the drain budget
            time.sleep(min(0.05, max(1e-4,
                                     todo[i].arrival - scheduler.now)))
            scheduler.now = scheduler.clock() - scheduler._t0
            continue
        if not scheduler.pending() and scheduler.clock == "modeled":
            # modeled-clocked and idle: the modeled clock only advances
            # with engine compute, so event-skip straight to the next
            # arrival instead of spinning empty iterations
            scheduler.now = max(scheduler.now, todo[i].arrival)
            continue
        scheduler.step(dt=dt)
        iters += 1
        if iters >= max_iterations:
            raise RuntimeError("closed loop did not drain")
    outputs = {offered[rid]: toks
               for rid, toks in scheduler.outputs().items()
               if rid in offered}
    return {"metrics": scheduler.metrics(), "outputs": outputs,
            "dropped": dropped}
