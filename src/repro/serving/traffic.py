"""Poisson traffic generation for closed-loop serving.

Builds the request stream the paper's low-batch scenario assumes:
arrivals are a Poisson process (exponential inter-arrival gaps at
``rate`` requests per time unit), request sizes come from the same
mixed prefill/decode splitter the chiplet simulator uses
(``sim.workload.make_requests`` — Poisson-sized prompts around
``avg_prompt``), and each request carries a private Zipf *affinity*
over the vocabulary (``sim.workload.sample_expert_probs`` with the
request's affinity seed): its prompt tokens are drawn from a skewed,
request-specific slice of the vocab, which is what produces the
long-tail expert activation the dynamic trajectory scheduler feeds on.

The same :class:`TrafficRequest` list replays into the simulator via
``to_sim_requests`` — engine and chiplet sim consume one workload.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import List

import numpy as np

from repro.sim import workload as sim_workload


@dataclass
class TrafficConfig:
    num_requests: int = 32
    rate: float = 0.5                # Poisson arrivals per time unit
    avg_prompt: int = 12             # mean prompt length (Poisson-sized)
    min_prompt: int = 1
    max_prompt: int = 64
    min_new: int = 2
    max_new: int = 8                 # output lengths uniform in [min,max]
    zipf_s: float = 1.1              # per-request token-affinity skew
    vocab: int = 256
    num_chiplets: int = 4            # home-chiplet striping for the sim
    seed: int = 0


@dataclass
class TrafficRequest:
    rid: str
    arrival: float
    prompt: List[int] = field(default_factory=list)
    max_new: int = 1
    affinity_seed: int = 0
    home_chiplet: int = 0


def make_traffic(cfg: TrafficConfig) -> List[TrafficRequest]:
    """Deterministic request stream for one (config, seed)."""
    rng = np.random.default_rng(cfg.seed)
    # request-size / affinity structure from the simulator's splitter:
    # ask for enough token budget that >= num_requests fall out, then
    # keep exactly num_requests
    sized: List[sim_workload.Request] = []
    budget = cfg.num_requests * max(1, cfg.avg_prompt)
    attempt = 0
    # growing the budget only *extends* the splitter's request list (the
    # rng sequence is a pure function of the seed), so the stream is
    # stable under retries and distinct across seeds
    while len(sized) < cfg.num_requests:
        sized = sim_workload.make_requests(
            budget, cfg.num_chiplets, cfg.seed,
            avg_request_tokens=cfg.avg_prompt)
        budget *= 2
        attempt += 1
        if attempt > 16:
            raise RuntimeError("traffic splitter failed to produce "
                               f"{cfg.num_requests} requests")
    sized = sized[:cfg.num_requests]

    out: List[TrafficRequest] = []
    now = 0.0
    for i, req in enumerate(sized):
        now += float(rng.exponential(1.0 / max(cfg.rate, 1e-9)))
        plen = int(np.clip(req.num_tokens, cfg.min_prompt, cfg.max_prompt))
        # per-request Zipf affinity over the vocab: a private permutation
        # of Zipf-ranked probabilities, seeded by the request's affinity
        # seed (the simulator uses the identical construction over experts)
        arng = np.random.default_rng(req.affinity_seed)
        probs = sim_workload.sample_expert_probs(cfg.vocab, arng,
                                                 zipf_s=cfg.zipf_s)
        prompt = arng.choice(cfg.vocab, size=plen, p=probs).tolist()
        max_new = int(rng.integers(cfg.min_new, cfg.max_new + 1))
        out.append(TrafficRequest(rid=f"traffic{i}", arrival=now,
                                  prompt=[int(t) for t in prompt],
                                  max_new=max_new,
                                  affinity_seed=req.affinity_seed,
                                  home_chiplet=req.home_chiplet))
    return out


def to_sim_requests(traffic: List[TrafficRequest]
                    ) -> List[sim_workload.Request]:
    """The same stream as simulator Requests (conformance replay)."""
    return [sim_workload.Request(rid=t.rid, num_tokens=len(t.prompt),
                                 home_chiplet=t.home_chiplet,
                                 affinity_seed=t.affinity_seed)
            for t in traffic]


def run_closed_loop(scheduler, traffic: List[TrafficRequest], *,
                    dt: float = 1.0, max_iterations: int = 100_000) -> dict:
    """Feed a traffic stream through a Scheduler until it drains.

    Arrival times are interpreted on the scheduler's clock (iteration
    counts advancing by ``dt`` per step unless the scheduler was built
    with a wall clock or ``clock="modeled"``): every request whose
    arrival time has passed is offered before the next step.  Returns ``{"metrics": ServingMetrics,
    "outputs": {rid: tokens}, "dropped": [rid, ...]}`` — dropped
    requests hit the bounded queue.
    """
    todo = sorted(traffic, key=lambda t: (t.arrival, t.rid))
    i = 0
    dropped: List[str] = []
    offered: dict = {}
    iters = 0
    while True:
        while i < len(todo) and todo[i].arrival <= scheduler.now:
            rid = scheduler.offer(todo[i].prompt, todo[i].max_new,
                                  arrival=todo[i].arrival)
            if rid is None:
                dropped.append(todo[i].rid)
            else:
                offered[rid] = todo[i].rid
            i += 1
        if i >= len(todo) and not scheduler.pending():
            break
        if not scheduler.pending() and callable(scheduler.clock):
            # wall-clocked and idle before the next arrival: sleep the
            # gap out (bounded slices so the loop stays responsive)
            # instead of burning engine iterations — idle waits do not
            # count against the drain budget
            time.sleep(min(0.05, max(1e-4,
                                     todo[i].arrival - scheduler.now)))
            scheduler.now = scheduler.clock() - scheduler._t0
            continue
        if not scheduler.pending() and scheduler.clock == "modeled":
            # modeled-clocked and idle: the modeled clock only advances
            # with engine compute, so event-skip straight to the next
            # arrival instead of spinning empty iterations
            scheduler.now = max(scheduler.now, todo[i].arrival)
            continue
        scheduler.step(dt=dt)
        iters += 1
        if iters >= max_iterations:
            raise RuntimeError("closed loop did not drain")
    outputs = {offered[rid]: toks
               for rid, toks in scheduler.outputs().items()
               if rid in offered}
    return {"metrics": scheduler.metrics(), "outputs": outputs,
            "dropped": dropped}
