"""Serving driver: low-batch decode with the layer-stepped engine
(chunked admission, continuous batching, Algorithm-2 token buffering).

  PYTHONPATH=src python -m repro.launch.serve --arch granite-moe-1b-a400m \
      --reduced --requests 6 --max-new 16 --slack 0.2

Closed-loop traffic mode (``--traffic``) drives the continuous-batching
scheduler with Poisson arrivals instead of a hand-fed batch: requests
stream through a bounded admission queue (``--queue-capacity``,
``--queue-policy``), prompts prefill in ``--chunk-tokens``-token chunks
piggybacked on the decode batch, and the run reports TTFT / TPOT /
queue-delay p50/p95/p99 plus throughput:

  PYTHONPATH=src python -m repro.launch.serve --arch granite-moe-1b-a400m \
      --reduced --traffic --traffic-requests 32 --traffic-rate 0.8 \
      --chunk-tokens 8

``--traffic --dry-run`` runs a tiny deterministic closed loop (CI smoke).
State lives in the paged pool (``--page-size``); ``--prefix-cache``
turns on content-hashed prompt-prefix reuse, ``--preempt-depth`` lets
the scheduler evict/restore requests under queue pressure, and
``--traffic-mix`` shapes the stream (``zipf_prefix`` shared system
prompts, ``diurnal`` arrival bursts).

MoE execution is configured by a single :class:`ExecutionSpec`
(``repro.core.strategy``): ``--strategy`` names a registered strategy
(fse_dp / ep / tp / hybrid / capacity / dense / auto), ``--moe-spec
path.json``
loads a full spec (per-phase + per-layer overrides, autotune level,
kernels/dispatch toggles); ``--autotune`` overrides the spec's level.
``--dry-run`` validates the spec (JSON round-trip + registry lookup) and
builds the engine through one tiny request without the full decode loop.
"""
from __future__ import annotations

import argparse
import time


def build_spec(args):
    import dataclasses
    from repro.core.strategy import ExecutionSpec
    if args.moe_spec:
        spec = ExecutionSpec.load(args.moe_spec)
        if args.strategy:
            spec = dataclasses.replace(spec, strategy=args.strategy)
    else:
        spec = ExecutionSpec(strategy=args.strategy or "capacity")
    # CLI overrides fold straight into the spec (ServeConfig's autotune
    # alias is deprecated)
    if getattr(args, "autotune", None):
        spec = dataclasses.replace(spec, autotune=args.autotune)
    if getattr(args, "schedule", None):
        spec = dataclasses.replace(spec, schedule=args.schedule)
    if getattr(args, "weight_dtype", None):
        spec = dataclasses.replace(spec, weight_dtype=args.weight_dtype)
    return spec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slack", type=float, default=0.0)
    ap.add_argument("--theta-min", type=int, default=2)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--strategy", default=None,
                    help="MoE execution strategy (registry name: fse_dp, "
                         "ep, tp, hybrid, capacity, dense, auto); "
                         "default capacity")
    ap.add_argument("--moe-spec", default=None,
                    help="path to an ExecutionSpec JSON (see "
                         "examples/moe-spec.json); --strategy overrides "
                         "its default strategy field")
    ap.add_argument("--autotune", choices=("off", "analytic", "measured"),
                    default=None,
                    help="override the spec's autotune level "
                         "(core.autotune); 'measured' times kernel "
                         "candidates once and caches them under "
                         "artifacts/autotune/")
    ap.add_argument("--schedule", choices=("static", "dynamic"),
                    default=None,
                    help="expert-trajectory scheduling (core.trajectory): "
                         "'dynamic' re-plans each layer's trajectory from "
                         "the EMA of observed gating counts (outputs are "
                         "bit-identical; execution order changes)")
    ap.add_argument("--weight-dtype", choices=("fp32", "bf16", "int8", "fp8"),
                    default=None,
                    help="streamed storage format for expert FFN weights "
                         "(kernels.quant): int8/fp8 quantize in-graph with "
                         "per-channel scales and halve/quarter the expert "
                         "DDR stream; default keeps params as-is (see "
                         "docs/quantization.md)")
    ap.add_argument("--resident-budget-mb", type=float, default=0.0,
                    help="EMA-hot expert weight tier: total bytes of "
                         "expert weights pinned resident on-package "
                         "(split evenly across MoE layers; hottest "
                         "experts by LoadTracker EMA); resident experts "
                         "skip their DDR stream in the modeled clock and "
                         "trace. 0 disables the tier")
    ap.add_argument("--hot-experts", type=int, default=None,
                    help="hybrid two-tier placement: fast-tier expert "
                         "count per MoE layer (default: top quartile, "
                         "strategy.default_hot); the engine repartitions "
                         "per iteration off the LoadTracker EMA and "
                         "records the hot ids in the trace")
    ap.add_argument("--dry-run", action="store_true",
                    help="validate the spec (JSON round-trip + registry) "
                         "and exercise one tiny request, then exit "
                         "(with --traffic: a tiny closed loop)")
    ap.add_argument("--traffic", action="store_true",
                    help="closed-loop mode: Poisson arrivals through the "
                         "continuous-batching scheduler (chunked prefill), "
                         "reporting TTFT/TPOT/queue-delay percentiles")
    ap.add_argument("--traffic-requests", type=int, default=32)
    ap.add_argument("--traffic-rate", type=float, default=0.5,
                    help="mean Poisson arrivals per second (wall clock)")
    ap.add_argument("--traffic-mix", default="poisson",
                    help="traffic mix: 'poisson' plus '+'-separated "
                         "modifiers 'zipf_prefix' (Zipf-shared system "
                         "prompts) and 'diurnal' (arrival-rate bursts), "
                         "e.g. poisson+zipf_prefix+diurnal")
    ap.add_argument("--avg-prompt", type=int, default=12)
    ap.add_argument("--chunk-tokens", type=int, default=8,
                    help="prefill chunk size piggybacked per iteration")
    ap.add_argument("--queue-capacity", type=int, default=64)
    ap.add_argument("--queue-policy", choices=("fcfs", "spf"),
                    default="fcfs")
    ap.add_argument("--page-size", type=int, default=8,
                    help="tokens per physical KV page in the state pool "
                         "(repro.serving.statepool)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="content-hash prompt prefixes and admit shared "
                         "prefixes with near-zero prefill compute")
    ap.add_argument("--preempt-depth", type=int, default=None,
                    help="queue depth past which the scheduler preempts "
                         "one running request per step to the state pool "
                         "(default: never preempt; 0 forces preemption "
                         "whenever the queue is non-empty and full)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import jax
    import numpy as np
    from repro.configs import get_config, reduced_config
    from repro.core.strategy import ExecutionSpec
    from repro.models import api
    from repro.serving import Engine, ServeConfig

    spec = build_spec(args)
    roundtrip = ExecutionSpec.from_json(spec.to_json())
    if roundtrip != spec:
        raise SystemExit(f"spec JSON round-trip mismatch:\n{spec}\n{roundtrip}")
    spec.validate()
    print(f"moe spec: {spec.to_json()}")

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    if args.reduced:
        cfg = cfg.replace(dtype="float32")
    params = api.init_params(jax.random.PRNGKey(args.seed), cfg)

    if args.traffic:
        from repro.serving import (Scheduler, SchedulerConfig, TrafficConfig,
                                   make_traffic, run_closed_loop)
        n_req = (args.traffic_requests if args.traffic_requests != 32
                 else 4) if args.dry_run else args.traffic_requests
        max_prompt = max(2, min(args.avg_prompt * 2,
                                args.prompt_len + args.avg_prompt))
        tcfg = TrafficConfig(num_requests=n_req, rate=args.traffic_rate,
                             avg_prompt=args.avg_prompt,
                             max_prompt=max_prompt, min_new=2,
                             max_new=args.max_new, vocab=cfg.vocab_size,
                             seed=args.seed, mix=args.traffic_mix)
        traffic = make_traffic(tcfg)
        need_ctx = max_prompt + args.max_new + 1
        eng = Engine(params, cfg, ServeConfig(
            max_batch=args.max_batch, max_ctx=need_ctx,
            buffering_slack=args.slack, theta_min=args.theta_min,
            chunk_tokens=args.chunk_tokens, spec=spec, seed=args.seed,
            page_size=args.page_size, prefix_cache=args.prefix_cache,
            preempt_queue_depth=args.preempt_depth,
            resident_budget_mb=args.resident_budget_mb,
            hot_experts=args.hot_experts))
        clock = None if args.dry_run else time.monotonic
        sched = Scheduler(eng, SchedulerConfig(
            queue_capacity=args.queue_capacity, policy=args.queue_policy),
            clock=clock)
        res = run_closed_loop(sched, traffic)
        m = res["metrics"]
        unit = "iters" if args.dry_run else "s"
        if args.dry_run and m.completed < n_req:
            raise SystemExit(f"traffic dry-run incomplete: "
                             f"{m.completed}/{n_req}")
        print(f"traffic: {m.completed} completed, {len(res['dropped'])} "
              f"dropped, {m.rejected} rejected, {m.iterations} iterations")
        for name, pct in (("ttft", m.ttft), ("tpot", m.tpot),
                          ("queue_delay", m.queue_delay)):
            print(f"  {name:12s} p50={pct['p50']:.3f} p95={pct['p95']:.3f} "
                  f"p99={pct['p99']:.3f} {unit}")
        print(f"  throughput   {m.throughput:.2f} tok/{unit} "
              f"({m.tokens_emitted} tokens, "
              f"{eng.stats['prefill_chunks']} prefill chunks, "
              f"{eng.stats['deferrals']} deferrals)")
        s = eng.stats
        print(f"  state pool   peak {s['pool_peak_pages']}/"
              f"{s['pool_pages']} pages, "
              f"{s['peak_resident_state_bytes']} peak resident bytes, "
              f"{s['cache_hits']} cache hits / {s['cache_misses']} misses "
              f"({s['prefill_tokens_saved']} prefill tokens saved), "
              f"{s['preemptions']} preemptions / {s['restores']} restores")
        print(f"  weight tier  {spec.weight_dtype or cfg.dtype} weights, "
              f"{s['resident_weight_bytes']} resident expert bytes "
              f"({eng._n_resident}/layer), "
              f"{s['ddr_bytes_saved']} DDR bytes saved")
        if args.dry_run and args.preempt_depth is not None \
                and s["preemptions"] < 1:
            raise SystemExit("preemption smoke: --preempt-depth was set "
                             "but no request was ever preempted — queue "
                             "pressure never materialized (check "
                             "--traffic-requests vs --max-batch)")
        if args.dry_run:
            print("traffic dry-run OK")
        return

    if args.dry_run:
        eng = Engine(params, cfg, ServeConfig(
            max_batch=2, max_ctx=16, spec=spec, seed=args.seed,
            resident_budget_mb=args.resident_budget_mb,
            hot_experts=args.hot_experts))
        eng.submit([1, 2, 3, 4], max_new=2)
        outs = eng.run(max_iterations=8)
        n = sum(len(t) for t in outs.values())
        if n < 1:
            raise SystemExit("dry-run emitted no tokens")
        s = eng.stats
        print(f"dry-run OK: spec={eng.scfg.spec.to_json()} tokens={n}")
        print(f"  weight tier  {spec.weight_dtype or cfg.dtype} weights, "
              f"{s['resident_weight_bytes']} resident expert bytes "
              f"({eng._n_resident}/layer), "
              f"{s['ddr_bytes_saved']} DDR bytes saved")
        return

    eng = Engine(params, cfg, ServeConfig(
        max_batch=args.max_batch, max_ctx=args.prompt_len + args.max_new + 8,
        buffering_slack=args.slack, theta_min=args.theta_min,
        spec=spec, seed=args.seed,
        resident_budget_mb=args.resident_budget_mb,
        hot_experts=args.hot_experts))

    rng = np.random.default_rng(args.seed)
    t0 = time.time()
    for i in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size, size=args.prompt_len).tolist()
        eng.submit(prompt, max_new=args.max_new)
    outs = eng.run()
    dt = time.time() - t0
    for rid, toks in outs.items():
        print(f"{rid}: {toks[:12]}{'...' if len(toks) > 12 else ''}")
    s = eng.stats
    print(f"tokens={s['tokens_emitted']} iterations={s['iterations']} "
          f"deferrals={s['deferrals']} expert_loads={s['expert_loads']} "
          f"loads_saved={s['expert_loads_saved']} "
          f"dynamic_schedules={s['dynamic_schedules']} "
          f"throughput={s['tokens_emitted']/dt:.1f} tok/s")
    print(f"weight tier: {spec.weight_dtype or cfg.dtype} weights, "
          f"{s['resident_weight_bytes']} resident expert bytes "
          f"({eng._n_resident}/layer), "
          f"{s['ddr_bytes_saved']} DDR bytes saved")


if __name__ == "__main__":
    main()
