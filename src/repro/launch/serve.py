"""Serving driver: low-batch decode with the layer-stepped engine
(chunked admission, continuous batching, Algorithm-2 token buffering).

  PYTHONPATH=src python -m repro.launch.serve --arch granite-moe-1b-a400m \
      --reduced --requests 6 --max-new 16 --slack 0.2
"""
from __future__ import annotations

import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slack", type=float, default=0.0)
    ap.add_argument("--theta-min", type=int, default=2)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--autotune", choices=("off", "analytic", "measured"),
                    default="analytic",
                    help="MoE trajectory/tile scheduler (core.autotune); "
                         "'measured' times kernel candidates once and caches "
                         "them under artifacts/autotune/")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import jax
    import numpy as np
    from repro.configs import get_config, reduced_config
    from repro.models import api
    from repro.serving import Engine, ServeConfig

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    if args.reduced:
        cfg = cfg.replace(dtype="float32")
    params = api.init_params(jax.random.PRNGKey(args.seed), cfg)
    eng = Engine(params, cfg, ServeConfig(
        max_batch=args.max_batch, max_ctx=args.prompt_len + args.max_new + 8,
        buffering_slack=args.slack, theta_min=args.theta_min,
        autotune=args.autotune, seed=args.seed))

    rng = np.random.default_rng(args.seed)
    t0 = time.time()
    for i in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size, size=args.prompt_len).tolist()
        eng.submit(prompt, max_new=args.max_new)
    outs = eng.run()
    dt = time.time() - t0
    for rid, toks in outs.items():
        print(f"{rid}: {toks[:12]}{'...' if len(toks) > 12 else ''}")
    s = eng.stats
    print(f"tokens={s['tokens_emitted']} iterations={s['iterations']} "
          f"deferrals={s['deferrals']} expert_loads={s['expert_loads']} "
          f"loads_saved={s['expert_loads_saved']} "
          f"throughput={s['tokens_emitted']/dt:.1f} tok/s")


if __name__ == "__main__":
    main()
