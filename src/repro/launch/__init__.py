from .mesh import make_production_mesh, make_mesh, data_axes
