import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape) cell on the
production mesh and record memory / cost / collective analysis.

MUST be run as its own process (the device-count flag is set before any
jax import):

  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --multi-pod

Artifacts land in artifacts/dryrun/<arch>__<shape>__<mesh>.json and are
consumed by benchmarks/roofline.py (EXPERIMENTS.md §Dry-run/§Roofline).
"""
import argparse
import json
import time
import traceback

import jax

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.configs.shapes import SHAPES, SHAPE_ORDER, applicable
from repro.kernels import ops as kops
from repro.launch import analysis
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_step
from repro.parallel import meshctx

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "artifacts", "dryrun")


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             out_dir: str = ARTIFACT_DIR, verbose: bool = True,
             distributed: bool = True, tag: str = "", opts: tuple = ()) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = applicable(cfg, shape)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "kind": shape.kind, "status": "skip", "reason": why, "tag": tag}
    if not ok:
        if verbose:
            print(f"[skip] {arch} × {shape_name}: {why}")
        return _write(rec, out_dir)

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    rec["opts"] = list(opts)
    t0 = time.time()
    try:
        with meshctx.with_mesh(mesh), kops.use_kernels(False), \
                meshctx.with_opts(*opts):
            fn, in_sh, out_sh, structs = build_step(cfg, shape, mesh,
                                                    distributed=distributed)
            if shape.kind == "train":
                donate = (0, 1)
            elif shape.kind == "prefill":
                donate = ()
            else:
                donate = (1,)
            jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                             donate_argnums=donate)
            lowered = jitted.lower(*structs)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = analysis.cost_dict(compiled)
        try:
            hlo = compiled.as_text()
        except Exception:
            hlo = lowered.as_text()
        coll = analysis.collective_bytes(hlo)

        mem_fields = {}
        for f in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
            mem_fields[f] = int(getattr(mem, f, 0) or 0)

        flops_dev = float(cost.get("flops", 0.0))
        bytes_dev = float(cost.get("bytes accessed", 0.0))
        rec.update({
            "status": "ok",
            "chips": int(chips),
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "memory": mem_fields,
            "per_device_bytes": mem_fields["argument_size_in_bytes"]
            + mem_fields["temp_size_in_bytes"],
            "cost_flops_per_device": flops_dev,
            "cost_bytes_per_device": bytes_dev,
            "collectives_per_device": coll,
            "model_flops": analysis.model_flops(cfg, shape),
            "params": cfg.param_count(),
            "active_params": cfg.active_param_count(),
            "hlo_ops": hlo.count("\n"),
            # XLA:CPU cost_analysis counts a while-loop (scan) body ONCE —
            # scan_trips lets the roofline scale the per-layer terms.
            "scan_trips": _scan_trips(cfg),
        })
        trips = rec["scan_trips"]
        rec["roofline"] = analysis.roofline_terms(
            flops_dev * trips * chips, bytes_dev * trips * chips,
            coll["total"] * trips * chips, chips)
        rec["roofline_uncorrected"] = analysis.roofline_terms(
            flops_dev * chips, bytes_dev * chips, coll["total"] * chips, chips)
        if verbose:
            print(f"[ok] {arch} × {shape_name} × {mesh_name}: "
                  f"lower {t_lower:.1f}s compile {t_compile:.1f}s "
                  f"mem/dev {rec['per_device_bytes']/2**30:.2f} GiB "
                  f"flops/dev {flops_dev:.3e} coll/dev {coll['total']:.3e}B "
                  f"dominant={rec['roofline']['dominant']}")
            print("  memory_analysis:", mem_fields)
    except Exception as e:  # record failures — they are bugs to fix
        rec.update({"status": "error", "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-2000:]})
        if verbose:
            print(f"[ERROR] {arch} × {shape_name} × {mesh_name}: {e}")
    return _write(rec, out_dir)


def _scan_trips(cfg) -> int:
    from repro.models.transformer import period_plan
    if cfg.is_encoder_decoder:
        return cfg.num_layers
    p, _ = period_plan(cfg)
    return cfg.num_layers // p


def _write(rec: dict, out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    tag = f"__{rec['tag']}" if rec.get("tag") else ""
    path = os.path.join(out_dir, f"{rec['arch']}__{rec['shape']}__{rec['mesh']}{tag}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1, default=str)
    return rec


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all",
                    help="arch id or 'all' (assigned 10)")
    ap.add_argument("--shape", default="all", choices=["all"] + SHAPE_ORDER)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=ARTIFACT_DIR)
    ap.add_argument("--tag", default="", help="artifact suffix (perf variants)")
    ap.add_argument("--opts", default="",
                    help="comma list: sorted,sp_attn,scatter_cache")
    args = ap.parse_args()
    opts = tuple(o for o in args.opts.split(",") if o)
    if opts and not args.tag:
        args.tag = "+".join(opts)

    archs = ASSIGNED_ARCHS if args.arch == "all" else [args.arch]
    shapes = SHAPE_ORDER if args.shape == "all" else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    failures = 0
    for arch in archs:
        for sh in shapes:
            for mp in meshes:
                rec = run_cell(arch, sh, multi_pod=mp, out_dir=args.out,
                               tag=args.tag, opts=opts)
                failures += rec["status"] == "error"
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
