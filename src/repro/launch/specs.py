"""ShapeDtypeStruct stand-ins for every model input of every
(architecture × shape) cell — weak-type-correct, shardable, and never
allocating (the dry-run pattern).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.configs.shapes import ShapeSpec
from repro.models import api

WHISPER_TEXT_LEN = 448      # decoder length for enc-dec train/prefill cells
WHISPER_MEMORY_LEN = 1500   # encoder memory length for decode cells

_KEY = jax.ShapeDtypeStruct((2,), jnp.uint32)


def params_struct(cfg: ModelConfig):
    return jax.eval_shape(lambda k: api.init_params(k, cfg), _KEY)


def batch_struct(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """Model inputs for a train/prefill cell (tokens + frontend stubs)."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    dt = jnp.dtype(cfg.dtype)
    if cfg.is_encoder_decoder:
        T = WHISPER_TEXT_LEN
        return {"frames": jax.ShapeDtypeStruct((B, S, cfg.d_model), dt),
                "tokens": jax.ShapeDtypeStruct((B, T), i32),
                "labels": jax.ShapeDtypeStruct((B, T), i32)}
    if cfg.frontend and cfg.frontend.kind == "vision":
        Pfx = cfg.frontend.num_prefix_tokens
        St = S - Pfx
        return {"prefix_embeds": jax.ShapeDtypeStruct((B, Pfx, cfg.d_model), dt),
                "tokens": jax.ShapeDtypeStruct((B, St), i32),
                "labels": jax.ShapeDtypeStruct((B, St), i32)}
    return {"tokens": jax.ShapeDtypeStruct((B, S), i32),
            "labels": jax.ShapeDtypeStruct((B, S), i32)}


def decode_structs(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[Any, Any, Any]:
    """(caches, token, cache_len) structs for a decode cell: one new token
    against a KV cache of seq_len."""
    B, S = shape.global_batch, shape.seq_len
    p = params_struct(cfg)
    caches = jax.eval_shape(
        lambda pp: api.init_decode_caches(pp, cfg, B, S, memory_len=WHISPER_MEMORY_LEN), p)
    token = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    cache_len = jax.ShapeDtypeStruct((B,), jnp.int32)
    return caches, token, cache_len


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """All ShapeDtypeStruct inputs for the cell's step function."""
    if shape.kind in ("train", "prefill"):
        return batch_struct(cfg, shape)
    caches, token, cache_len = decode_structs(cfg, shape)
    return {"caches": caches, "token": token, "cache_len": cache_len}
