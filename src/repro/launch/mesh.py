"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module does not touch jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh (tests / examples use small host-device meshes)."""
    return jax.make_mesh(tuple(shape), tuple(axes))


def data_axes(mesh) -> tuple:
    """Axes that shard the batch: every axis except 'model'."""
    return tuple(a for a in mesh.axis_names if a != "model")
