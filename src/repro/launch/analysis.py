"""Compiled-artifact analysis: collective-byte parsing + roofline terms.

collective_bytes is not in ``cost_analysis()`` — we parse the
(post-SPMD, per-device) HLO text and sum the output-shape bytes of
every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute op ("-start" variants counted once, "-done"
skipped).
"""
from __future__ import annotations

import re
from typing import Dict

# v5e-class hardware constants (per chip)
PEAK_FLOPS = 197e12          # bf16 FLOP/s
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link
VMEM_BYTES = 16 * 2 ** 20    # per-core fast memory (kernel working-set budget)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s*"
    r"([a-z0-9\-]+)(?:-start)?\(", re.M)


def cost_dict(compiled) -> Dict[str, float]:
    """``Compiled.cost_analysis()`` normalized to a flat dict — jax returns
    a list with one dict per device program on some versions/backends."""
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost)


def lowering_cost(fn, *args) -> Dict[str, float]:
    """Lower+compile ``fn`` on ``args`` and return its normalized XLA cost
    dict — the predicted-side record of the measured-autotune cache (the
    autotuner stores these next to wall-clock times per tile candidate)."""
    import jax
    compiled = jax.jit(fn).lower(*args).compile()
    return cost_dict(compiled)


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-category byte totals from HLO text (per device)."""
    out = {c: 0.0 for c in _COLLECTIVES}
    out["count"] = 0
    for m in _OP_RE.finditer(hlo_text):
        shape_str, op = m.group(1), m.group(2)
        base = op[:-6] if op.endswith("-start") else op
        if base.endswith("-done"):
            continue
        if base in _COLLECTIVES:
            out[base] += _shape_bytes(shape_str)
            out["count"] += 1
    out["total"] = sum(out[c] for c in _COLLECTIVES)
    return out


def roofline_terms(flops: float, hbm_bytes: float, coll_bytes: float,
                   chips: int) -> Dict[str, float]:
    """Three roofline terms in seconds.

    ``flops``/``hbm_bytes``/``coll_bytes`` are GLOBAL totals (summed over
    devices); the dry-run records per-device numbers × chips.
    """
    compute = flops / (chips * PEAK_FLOPS)
    memory = hbm_bytes / (chips * HBM_BW)
    collective = coll_bytes / (chips * ICI_BW)
    dom = max(("compute", compute), ("memory", memory),
              ("collective", collective), key=lambda kv: kv[1])
    return {"compute_s": compute, "memory_s": memory, "collective_s": collective,
            "dominant": dom[0], "bound_s": dom[1]}


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS: 6·N·D train / 2·N·D prefill / 2·N·B decode (active params)."""
    n_act = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n_act * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_act * shape.global_batch * shape.seq_len
    return 2.0 * n_act * shape.global_batch            # one token per sequence
