"""End-to-end training driver.

On this CPU container it trains reduced configs (see
examples/train_moe_100m.py for the ~100M driver); on a real pod the
same entry point jits ``build_train_step`` onto the production mesh.

  PYTHONPATH=src python -m repro.launch.train --arch granite-moe-1b-a400m \
      --reduced --steps 100 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="train the smoke-scale config (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--grad-compress-bits", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.configs import get_config, reduced_config
    from repro.data import DataConfig
    from repro.training import TrainConfig, train

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    if args.reduced:
        cfg = cfg.replace(dtype="float32")
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                      global_batch=args.batch, seed=args.seed)
    tcfg = TrainConfig(lr=args.lr, total_steps=args.steps,
                       warmup=max(5, args.steps // 20),
                       ckpt_dir=args.ckpt_dir,
                       grad_compress_bits=args.grad_compress_bits,
                       log_every=max(1, args.steps // 20))

    def log(step, metrics):
        print(f"step {step:5d}  loss {metrics['loss']:.4f}  "
              f"ce {metrics['ce']:.4f}  gnorm {metrics['grad_norm']:.2f}")

    res = train(cfg, dcfg, tcfg, seed=args.seed, hooks=log)
    print(f"done: {res.final_step} steps in {res.wall_time:.1f}s "
          f"(resumed_from={res.resumed_from})")


if __name__ == "__main__":
    main()
