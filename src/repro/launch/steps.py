"""Step functions (train / prefill / serve) with production shardings.

``build_*`` returns (fn, in_shardings, out_shardings, arg_structs) ready
for ``jax.jit(fn, in_shardings=..., out_shardings=...).lower(*structs)``
— the dry-run contract.  The same builders drive the real train/serve
entry points on actual hardware.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.configs.shapes import ShapeSpec
from repro.models import api
from repro.optim import adamw
from repro.parallel import sharding as shd
from repro.parallel.sharding import FSDP_THRESHOLD
from . import specs as S
from .mesh import data_axes


def _moe_spec(cfg: ModelConfig, distributed: bool):
    """The step's MoE ExecutionSpec: the arch's configured strategy when
    tracing for the production mesh, the single-device capacity path
    otherwise (see ``repro.core.strategy``)."""
    if cfg.moe is None:
        return None
    from repro.core.strategy import ExecutionSpec
    return ExecutionSpec(strategy=cfg.moe.impl if distributed else "capacity")


def needs_fsdp(cfg: ModelConfig) -> bool:
    return cfg.param_count() > FSDP_THRESHOLD


def needs_fsdp_infer(cfg: ModelConfig) -> bool:
    """Inference shards params over 'model' only unless bf16 params
    exceed ~12 GB/chip on the 16-wide model axis (nemotron-4-340b).
    (FSDP at decode would re-gather weights every token — and conflicts
    with the FSE-DP shard_map weight specs.)"""
    return cfg.param_count() * 2 / 16 > 12e9


def state_dtype(cfg: ModelConfig):
    """bf16 optimizer state above the FSDP threshold (fits 16 GB/chip)."""
    return jnp.bfloat16 if needs_fsdp(cfg) else jnp.float32


# ---------------------------------------------------------------------------
# train
# ---------------------------------------------------------------------------

def build_train_step(cfg: ModelConfig, shape: ShapeSpec, mesh, *,
                     lr: float = 1e-4, distributed: bool = True,
                     remat: Optional[bool] = None):
    fsdp = needs_fsdp(cfg)
    remat = True if remat is None else remat   # scan-over-layers without remat
                                               # saves every layer's MoE dispatch
                                               # masks — O(L·T·E·C) activation
    spec = _moe_spec(cfg, distributed)
    baxes = data_axes(mesh)

    def train_step(params, opt_state, batch):
        def loss(p):
            return api.loss_fn(p, batch, cfg, spec=spec, remat=remat,
                               unshard=fsdp)
        (l, metrics), grads = jax.value_and_grad(loss, has_aux=True)(params)
        params2, opt2, om = adamw.apply(params, grads, opt_state, lr=lr)
        return params2, opt2, l

    pstruct = S.params_struct(cfg)
    ostruct = jax.eval_shape(partial(adamw.init, state_dtype=state_dtype(cfg)), pstruct)
    bstruct = S.batch_struct(cfg, shape)

    psh = shd.param_shardings(pstruct, mesh, fsdp=fsdp)
    osh = shd.opt_shardings(ostruct, pstruct, mesh, fsdp=fsdp)
    bsh = shd.batch_shardings(bstruct, mesh, baxes)
    rep = shd.replicated(mesh)

    in_sh = (psh, osh, bsh)
    out_sh = (psh, osh, rep)
    return train_step, in_sh, out_sh, (pstruct, ostruct, bstruct)


# ---------------------------------------------------------------------------
# prefill
# ---------------------------------------------------------------------------

def build_prefill_step(cfg: ModelConfig, shape: ShapeSpec, mesh, *,
                       distributed: bool = True):
    spec = _moe_spec(cfg, distributed)
    baxes = data_axes(mesh)

    def prefill_step(params, batch):
        logits, caches = api.prefill_fn(params, batch, cfg, shape.seq_len,
                                        spec=spec)
        # serving needs only the last position to start decoding; returning
        # the full (B,S,V) tensor forces a ~60 GiB vocab unshard (§Perf B2)
        return logits[:, -1:], caches

    pstruct = S.params_struct(cfg)
    bstruct = S.batch_struct(cfg, shape)
    out_struct = jax.eval_shape(prefill_step, pstruct, bstruct)

    psh = shd.param_shardings(pstruct, mesh, fsdp=needs_fsdp_infer(cfg))
    bsh = shd.batch_shardings(bstruct, mesh, baxes)
    logit_sh = jax.sharding.NamedSharding(
        mesh, shd.batch_spec("logits", out_struct[0].shape, mesh, baxes))
    cache_sh = shd.cache_shardings(out_struct[1], mesh, baxes)
    return prefill_step, (psh, bsh), (logit_sh, cache_sh), (pstruct, bstruct)


# ---------------------------------------------------------------------------
# serve (single-token decode against a full KV cache)
# ---------------------------------------------------------------------------

def build_serve_step(cfg: ModelConfig, shape: ShapeSpec, mesh, *,
                     distributed: bool = True):
    spec = _moe_spec(cfg, distributed)
    baxes = data_axes(mesh)

    fsdp_i = needs_fsdp_infer(cfg)

    def serve_step(params, caches, token, cache_len):
        logits, new_caches = api.decode_fn(params, token, caches, cache_len, cfg,
                                           spec=spec, unshard=fsdp_i)
        return logits, new_caches

    pstruct = S.params_struct(cfg)
    cstruct, tstruct, lstruct = S.decode_structs(cfg, shape)

    psh = shd.param_shardings(pstruct, mesh, fsdp=needs_fsdp_infer(cfg))
    csh = shd.cache_shardings(cstruct, mesh, baxes)
    tsh = shd.batch_shardings({"token": tstruct, "cache_len": lstruct}, mesh, baxes)
    rep = shd.replicated(mesh)
    logit_sh = jax.sharding.NamedSharding(
        mesh, shd.batch_spec("logits", (shape.global_batch, 1, cfg.vocab_size),
                             mesh, baxes))
    in_sh = (psh, csh, tsh["token"], tsh["cache_len"])
    out_sh = (logit_sh, csh)
    return serve_step, in_sh, out_sh, (pstruct, cstruct, tstruct, lstruct)


def build_step(cfg: ModelConfig, shape: ShapeSpec, mesh, **kw):
    if shape.kind == "train":
        return build_train_step(cfg, shape, mesh, **kw)
    if shape.kind == "prefill":
        return build_prefill_step(cfg, shape, mesh, **kw)
    return build_serve_step(cfg, shape, mesh, **kw)
