from .trainer import TrainConfig, TrainResult, train, make_train_step
