"""Training loop: jit'd AdamW step, checkpoint/restart, auto-resume,
optional int8 gradient compression on the cross-pod axis.

Fault-tolerance contract: the checkpoint holds (params, opt state,
step); the data pipeline is a pure function of step; a crash at any
point resumes bitwise-identically from the last published checkpoint
(tested in tests/test_train.py by killing and restarting mid-run).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.checkpointing import manager as ckpt
from repro.configs.base import ModelConfig
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import api
from repro.optim import adamw, compress


@dataclass
class TrainConfig:
    lr: float = 3e-4
    warmup: int = 20
    total_steps: int = 200
    weight_decay: float = 0.1
    max_grad_norm: float = 1.0
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    keep_ckpts: int = 3
    grad_compress_bits: int = 0       # 0 = off; 8 = int8 + error feedback
    moe_spec: Optional[Any] = None    # MoE ExecutionSpec / strategy name
    moe_impl: Optional[str] = None    # deprecated alias for moe_spec
    remat: bool = False
    log_every: int = 10
    state_dtype: str = "float32"


_MOE_IMPL_WARNED = False


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig):
    sched = adamw.cosine_schedule(tcfg.lr, tcfg.warmup, tcfg.total_steps)

    if tcfg.moe_impl is not None:
        global _MOE_IMPL_WARNED
        if not _MOE_IMPL_WARNED:
            _MOE_IMPL_WARNED = True
            import warnings
            warnings.warn("TrainConfig.moe_impl is deprecated; use "
                          "TrainConfig.moe_spec (see README migration "
                          "table)", DeprecationWarning, stacklevel=2)
    spec = tcfg.moe_spec if tcfg.moe_spec is not None else tcfg.moe_impl

    def step_fn(params, opt_state, batch, residual):
        def loss(p):
            l, metrics = api.loss_fn(p, batch, cfg, spec=spec,
                                     remat=tcfg.remat)
            return l, metrics
        (lval, metrics), grads = jax.value_and_grad(loss, has_aux=True)(params)
        if tcfg.grad_compress_bits:
            grads, residual = compress.compress_tree(
                grads, residual, bits=tcfg.grad_compress_bits)
        params, opt_state, om = adamw.apply(
            params, grads, opt_state, lr=sched,
            weight_decay=tcfg.weight_decay, max_grad_norm=tcfg.max_grad_norm)
        metrics = dict(metrics, loss=lval, **om)
        return params, opt_state, residual, metrics

    return jax.jit(step_fn, donate_argnums=(0, 1, 3))


@dataclass
class TrainResult:
    losses: list = field(default_factory=list)
    final_step: int = 0
    resumed_from: Optional[int] = None
    wall_time: float = 0.0


def train(cfg: ModelConfig, dcfg: DataConfig, tcfg: TrainConfig,
          *, seed: int = 0, hooks: Optional[Callable[[int, dict], None]] = None,
          crash_at_step: Optional[int] = None) -> TrainResult:
    """Run (or resume) training. ``crash_at_step`` simulates preemption
    (raises) — the fault-tolerance tests restart and assert continuity."""
    key = jax.random.PRNGKey(seed)
    params = api.init_params(key, cfg)
    opt_state = adamw.init(params, jnp.dtype(tcfg.state_dtype))
    residual = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params) \
        if tcfg.grad_compress_bits else jnp.zeros(())
    start = 0
    resumed = None
    if tcfg.ckpt_dir and ckpt.latest_step(tcfg.ckpt_dir) is not None:
        state = {"params": params, "opt": opt_state, "residual": residual}
        state, start, _ = ckpt.restore(tcfg.ckpt_dir, state)
        params, opt_state, residual = state["params"], state["opt"], state["residual"]
        resumed = start

    data = SyntheticLM(dcfg)
    step_fn = make_train_step(cfg, tcfg)
    result = TrainResult(resumed_from=resumed)
    t0 = time.time()

    for step in range(start, tcfg.total_steps):
        if crash_at_step is not None and step == crash_at_step:
            raise RuntimeError(f"simulated preemption at step {step}")
        batch = {k: jnp.asarray(v) for k, v in data.batch(step).items()}
        params, opt_state, residual, metrics = step_fn(params, opt_state, batch, residual)
        if step % tcfg.log_every == 0 or step == tcfg.total_steps - 1:
            loss = float(metrics["loss"])
            result.losses.append((step, loss))
            if hooks:
                hooks(step, {k: float(v) for k, v in metrics.items()})
        if tcfg.ckpt_dir and ((step + 1) % tcfg.ckpt_every == 0
                              or step == tcfg.total_steps - 1):
            state = {"params": params, "opt": opt_state, "residual": residual}
            ckpt.save(tcfg.ckpt_dir, step + 1, state)
            ckpt.gc_old(tcfg.ckpt_dir, tcfg.keep_ckpts)

    result.final_step = tcfg.total_steps
    result.wall_time = time.time() - t0
    result.params = params  # type: ignore[attr-defined]
    return result
