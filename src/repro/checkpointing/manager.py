"""Atomic, mesh-agnostic checkpointing with auto-resume.

Layout:
  <dir>/step_0000100.tmp-<pid>/   (written fully, fsync'd)
  <dir>/step_0000100/             (atomic rename — crash-safe)
  <dir>/LATEST                    (text pointer, written last)

Arrays are stored as a flat path->npy mapping; restore reshards onto
the *current* mesh/sharding (elastic restart: a checkpoint taken on a
512-chip mesh reloads onto whatever mesh is alive).
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = leaf
    return flat


def save(ckpt_dir: str, step: int, tree: Any, extra: Optional[dict] = None) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + f".tmp-{os.getpid()}"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(tree)
    arrays = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    meta = {"step": step, "keys": sorted(arrays.keys()), "extra": extra or {}}
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                      # atomic publish
    with open(os.path.join(ckpt_dir, "LATEST.tmp"), "w") as f:
        f.write(os.path.basename(final))
        f.flush()
        os.fsync(f.fileno())
    os.replace(os.path.join(ckpt_dir, "LATEST.tmp"),
               os.path.join(ckpt_dir, "LATEST"))
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    ptr = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(ptr):
        return None
    name = open(ptr).read().strip()
    path = os.path.join(ckpt_dir, name)
    if not os.path.isdir(path):                # pointer ahead of a crash
        steps = sorted(int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
                       if d.startswith("step_") and not d.endswith("tmp"))
        return steps[-1] if steps else None
    return int(name.split("_")[1])


def restore(ckpt_dir: str, like: Any, step: Optional[int] = None,
            shardings: Any = None):
    """Restore into the structure of ``like`` (shapes/dtypes preserved).

    ``shardings``: optional matching pytree of NamedSharding — arrays are
    device_put onto it (elastic reshard onto the current mesh).
    Returns (tree, step, extra).
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with np.load(os.path.join(path, "arrays.npz")) as z:
        data = {k: z[k] for k in z.files}
    meta = json.load(open(os.path.join(path, "meta.json")))

    flat_like = _flatten(like)
    missing = set(flat_like) - set(data)
    if missing:
        raise KeyError(f"checkpoint missing keys: {sorted(missing)[:5]} ...")
    flat_shard = _flatten(shardings) if shardings is not None else {}

    def rebuild(tree):
        leaves_path = jax.tree_util.tree_flatten_with_path(tree)
        out = []
        for path_, leaf in leaves_path[0]:
            key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path_)
            arr = jnp.asarray(data[key], dtype=leaf.dtype)
            if key in flat_shard:
                arr = jax.device_put(arr, flat_shard[key])
            out.append(arr)
        return jax.tree_util.tree_unflatten(leaves_path[1], out)

    return rebuild(like), meta["step"], meta.get("extra", {})


def all_steps(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return []
    return sorted(int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
                  if d.startswith("step_") and ".tmp" not in d)


def gc_old(ckpt_dir: str, keep: int = 3):
    steps = all_steps(ckpt_dir)
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"), ignore_errors=True)
