from . import manager
