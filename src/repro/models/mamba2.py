"""Mamba-2 (SSD — state-space duality) block.

Used by ``mamba2-370m`` (d_state=128) and for Jamba's Mamba layers
(d_state=16; see DESIGN.md §2 assumption log).

The chunked SSD forward follows the Mamba-2 paper's minimal listing;
``repro.kernels.ssd`` provides the Pallas intra-chunk kernel and
``ssd_naive`` here is the exact sequential oracle used by tests and by
the single-token decode step.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import SSMConfig
from .layers import dense_init


class SSMState(NamedTuple):
    conv: jnp.ndarray     # (B, d_conv-1, d_xBC)   rolling conv window
    ssd: jnp.ndarray      # (B, H, P, N)           recurrent state


def mamba2_init(key, d_model, ssm: SSMConfig, dtype):
    di = ssm.expand * d_model
    nh = di // ssm.head_dim
    d_xBC = di + 2 * ssm.n_groups * ssm.d_state
    ks = jax.random.split(key, 6)
    dt = jnp.exp(jax.random.uniform(ks[3], (nh,), jnp.float32)
                 * (jnp.log(ssm.dt_max) - jnp.log(ssm.dt_min)) + jnp.log(ssm.dt_min))
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))   # inverse softplus
    return {
        "in_proj": dense_init(ks[0], d_model, 2 * di + 2 * ssm.n_groups * ssm.d_state + nh, dtype),
        "conv_w": (jax.random.normal(ks[1], (ssm.d_conv, d_xBC), jnp.float32) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((d_xBC,), dtype),
        "out_proj": dense_init(ks[2], di, d_model, dtype),
        "A_log": jnp.log(jnp.arange(1, nh + 1, dtype=jnp.float32)),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": dt_bias.astype(jnp.float32),
    }


# ---------------------------------------------------------------------------
# SSD core
# ---------------------------------------------------------------------------

def segsum(x):
    """x: (..., T) -> (..., T, T); out[..., i, j] = sum_{k=j+1..i} x[k], -inf above diag."""
    T = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    keep = jnp.tril(jnp.ones((T, T), bool), 0)
    return jnp.where(keep, out, -jnp.inf)


def ssd_chunked(x, dt, A, Bm, Cm, chunk, initial_state=None, use_kernel=False):
    """Chunked SSD scan.

    x:  (b, l, h, p)   inputs per head
    dt: (b, l, h)      positive step sizes (post-softplus)
    A:  (h,)           negative decay rates
    Bm, Cm: (b, l, g, n) with g==1 (broadcast over heads)
    Returns y: (b, l, h, p), final_state: (b, h, p, n)
    """
    b, l, h, p = x.shape
    n = Bm.shape[-1]
    assert l % chunk == 0, (l, chunk)
    nc = l // chunk
    # broadcast groups to heads
    Bh = jnp.broadcast_to(Bm, (b, l, 1, n)) if Bm.shape[2] == 1 else Bm
    Ch = jnp.broadcast_to(Cm, (b, l, 1, n)) if Cm.shape[2] == 1 else Cm
    Bh = jnp.repeat(Bh, h // Bh.shape[2], axis=2)
    Ch = jnp.repeat(Ch, h // Ch.shape[2], axis=2)

    # operands stay in the model dtype (bf16 on pods) — fp32 only inside
    # the (checkpointed, recomputed) per-chunk math; halves the resident
    # SSD activations at 4k-train scale
    xd = (x * dt[..., None].astype(x.dtype))
    dA = (dt * A[None, None, :]).astype(jnp.float32)               # (b,l,h) negative

    # chunk views
    xc = xd.reshape(b, nc, chunk, h, p)
    Bc = Bh.reshape(b, nc, chunk, h, n)
    Cc = Ch.reshape(b, nc, chunk, h, n)
    Ac = dA.reshape(b, nc, chunk, h).transpose(0, 3, 1, 2)         # (b,h,nc,chunk)
    A_cumsum = jnp.cumsum(Ac, axis=-1)                             # (b,h,nc,chunk)

    if use_kernel:
        from repro.kernels import ssd_ops
        Y_diag, states = ssd_ops.ssd_intra_chunk(
            xc.astype(jnp.float32), Bc.astype(jnp.float32),
            Cc.astype(jnp.float32), Ac, A_cumsum)
    elif nc >= 16:
        # long sequences: scan over chunks so only one (c,c) semiseparable
        # mask is live at a time (O(nc·c²) -> O(c²) memory); checkpointed so
        # the backward also recomputes per chunk instead of saving every
        # chunk's (c,c,h) score tensor (8 GiB/layer for Jamba at 4k train)
        @jax.checkpoint
        def intra(args):
            xi, Bi, Ci, Ai, Aci = args                             # per-chunk
            xi = xi.astype(jnp.float32)
            Bi = Bi.astype(jnp.float32)
            Ci = Ci.astype(jnp.float32)
            Li = jnp.exp(segsum(Ai))                               # (b,h,c,c)
            Yi = jnp.einsum("blhn,bshn,bhls,bshp->blhp", Ci, Bi, Li, xi)
            dec = jnp.exp(Aci[:, :, -1:] - Aci)                    # (b,h,c)
            Si = jnp.einsum("blhn,bhl,blhp->bhpn", Bi, dec, xi)
            return Yi, Si

        args = (jnp.moveaxis(xc, 1, 0), jnp.moveaxis(Bc, 1, 0),
                jnp.moveaxis(Cc, 1, 0), jnp.moveaxis(Ac, 2, 0),
                jnp.moveaxis(A_cumsum, 2, 0))
        Y_diag, states = jax.lax.map(intra, args)
        Y_diag = jnp.moveaxis(Y_diag, 0, 1)                        # (b,nc,c,h,p)
        states = jnp.moveaxis(states, 0, 1)                        # (b,nc,h,p,n)
    else:
        xf = xc.astype(jnp.float32)
        Bf = Bc.astype(jnp.float32)
        Cf = Cc.astype(jnp.float32)
        L = jnp.exp(segsum(Ac))                                    # (b,h,nc,c,c)
        Y_diag = jnp.einsum("bclhn,bcshn,bhcls,bcshp->bclhp", Cf, Bf, L, xf)
        decay_states = jnp.exp(A_cumsum[:, :, :, -1:] - A_cumsum)  # (b,h,nc,c)
        states = jnp.einsum("bclhn,bhcl,bclhp->bchpn", Bf, decay_states, xf)

    # inter-chunk recurrence
    if initial_state is None:
        initial_state = jnp.zeros((b, h, p, n), jnp.float32)
    states = jnp.concatenate([initial_state[:, None], states], axis=1)  # (b,nc+1,h,p,n)
    chunk_decay = A_cumsum[:, :, :, -1]                                 # (b,h,nc)
    pad = jnp.pad(chunk_decay, ((0, 0), (0, 0), (1, 0)))
    decay_chunk = jnp.exp(segsum(pad))                                  # (b,h,nc+1,nc+1)
    new_states = jnp.einsum("bhzc,bchpn->bzhpn", decay_chunk, states)
    prev_states, final_state = new_states[:, :-1], new_states[:, -1]

    state_decay_out = jnp.exp(A_cumsum)                                 # (b,h,nc,c)
    Y_off = jnp.einsum("bclhn,bchpn,bhcl->bclhp", Cc.astype(jnp.float32),
                       prev_states, state_decay_out)
    y = (Y_diag + Y_off).reshape(b, l, h, p)
    return y.astype(x.dtype), final_state


def ssd_naive(x, dt, A, Bm, Cm, initial_state=None):
    """Exact sequential recurrence oracle: S_t = S exp(dt A) + dt x B^T."""
    b, l, h, p = x.shape
    n = Bm.shape[-1]
    Bh = jnp.repeat(Bm, h // Bm.shape[2], axis=2).astype(jnp.float32)
    Ch = jnp.repeat(Cm, h // Cm.shape[2], axis=2).astype(jnp.float32)
    S0 = initial_state if initial_state is not None else jnp.zeros((b, h, p, n), jnp.float32)

    def step(S, t):
        xt, dtt, Bt, Ct = x[:, t].astype(jnp.float32), dt[:, t], Bh[:, t], Ch[:, t]
        decay = jnp.exp(dtt * A[None, :])[..., None, None]          # (b,h,1,1)
        S = S * decay + jnp.einsum("bhp,bhn->bhpn", xt * dtt[..., None], Bt)
        y = jnp.einsum("bhn,bhpn->bhp", Ct, S)
        return S, y

    S, ys = jax.lax.scan(step, S0, jnp.arange(l))
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), S


def ssd_decode_step(state, xt, dtt, A, Bt, Ct):
    """One-token recurrence. state: (b,h,p,n); xt: (b,h,p); dtt: (b,h)."""
    decay = jnp.exp(dtt * A[None, :])[..., None, None]
    state = state * decay + jnp.einsum("bhp,bhn->bhpn",
                                       xt.astype(jnp.float32) * dtt[..., None],
                                       Bt.astype(jnp.float32))
    y = jnp.einsum("bhn,bhpn->bhp", Ct.astype(jnp.float32), state)
    return state, y.astype(xt.dtype)


# ---------------------------------------------------------------------------
# full block
# ---------------------------------------------------------------------------

def _causal_conv(x, w, b):
    """Depthwise causal conv. x: (B,L,D); w: (K,D)."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(K))
    return out + b


def mamba2_block(params, x, ssm: SSMConfig, d_model, use_kernel=False):
    """Full-sequence forward. x: (B,L,d) -> (B,L,d)."""
    B_, L, _ = x.shape
    di = ssm.expand * d_model
    nh = di // ssm.head_dim
    g, n = ssm.n_groups, ssm.d_state

    zxbcdt = x @ params["in_proj"]
    z, xBC, dt = jnp.split(zxbcdt, [di, di + di + 2 * g * n], axis=-1)
    xBC = jax.nn.silu(_causal_conv(xBC, params["conv_w"], params["conv_b"]))
    xs, Bm, Cm = jnp.split(xBC, [di, di + g * n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])     # (B,L,nh)
    A = -jnp.exp(params["A_log"])                                        # (nh,)

    xh = xs.reshape(B_, L, nh, ssm.head_dim)
    Bm = Bm.reshape(B_, L, g, n)
    Cm = Cm.reshape(B_, L, g, n)
    chunk = min(ssm.chunk_size, L)
    if L % chunk:
        chunk = 1  # degenerate fallback for odd lengths
    y, _ = ssd_chunked(xh, dt, A, Bm, Cm, chunk, use_kernel=use_kernel)
    y = y + params["D"][None, None, :, None] * xh                        # skip
    y = (y.reshape(B_, L, di) * jax.nn.silu(z)).astype(x.dtype)
    return y @ params["out_proj"]


def mamba2_prefill(params, x, ssm: SSMConfig, d_model):
    """Full forward also returning the final SSMState for decode."""
    B_, L, _ = x.shape
    di = ssm.expand * d_model
    nh = di // ssm.head_dim
    g, n = ssm.n_groups, ssm.d_state
    zxbcdt = x @ params["in_proj"]
    z, xBC_raw, dt = jnp.split(zxbcdt, [di, di + di + 2 * g * n], axis=-1)
    xBC = jax.nn.silu(_causal_conv(xBC_raw, params["conv_w"], params["conv_b"]))
    xs, Bm, Cm = jnp.split(xBC, [di, di + g * n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    xh = xs.reshape(B_, L, nh, ssm.head_dim)
    chunk = min(ssm.chunk_size, L)
    if L % chunk:
        chunk = 1
    y, final = ssd_chunked(xh, dt, A, Bm.reshape(B_, L, g, n), Cm.reshape(B_, L, g, n), chunk)
    y = y + params["D"][None, None, :, None] * xh
    y = (y.reshape(B_, L, di) * jax.nn.silu(z)).astype(x.dtype)
    K = params["conv_w"].shape[0]
    conv_state = xBC_raw[:, -(K - 1):, :] if L >= K - 1 else jnp.pad(
        xBC_raw, ((0, 0), (K - 1 - L, 0), (0, 0)))
    return y @ params["out_proj"], SSMState(conv_state, final)


def mamba2_chunk(params, x, state: SSMState, ssm: SSMConfig, d_model,
                 token_mask=None):
    """Chunked-prefill step: advance the SSM by a K-token chunk.

    x: (B,K,d); ``state`` carries the rolling conv window and the SSD
    recurrent state from previous chunks (``init_ssm_state`` zeros for
    the first chunk).  ``token_mask`` (B,K) marks the valid chunk
    *prefix* per row: masked tail steps have their dt zeroed, so the
    decay is exp(0)=1 and the input contribution is 0 — the recurrent
    state passes through them untouched, and the conv window is rebuilt
    from the last valid inputs, so an all-False row is a bit-exact
    no-op.  Uses the exact sequential recurrence (``ssd_naive``), the
    same oracle the one-token decode step follows.

    Returns (y (B,K,d), new SSMState).
    """
    B_, L, _ = x.shape
    di = ssm.expand * d_model
    nh = di // ssm.head_dim
    g, n = ssm.n_groups, ssm.d_state
    zxbcdt = x @ params["in_proj"]
    z, xBC_raw, dt = jnp.split(zxbcdt, [di, di + di + 2 * g * n], axis=-1)
    Kc = params["conv_w"].shape[0]
    # causal conv over [carried window | chunk] — same window sum as
    # _causal_conv but seeded with the previous chunk's tail instead of
    # zero padding (matches the decode step's rolling window)
    cat = jnp.concatenate([state.conv.astype(xBC_raw.dtype), xBC_raw], axis=1)
    xBC = sum(cat[:, i:i + L, :] * params["conv_w"][i] for i in range(Kc)) \
        + params["conv_b"]
    xBC = jax.nn.silu(xBC)
    xs, Bm, Cm = jnp.split(xBC, [di, di + g * n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    if token_mask is not None:
        dt = dt * token_mask[..., None].astype(dt.dtype)
    A = -jnp.exp(params["A_log"])
    xh = xs.reshape(B_, L, nh, ssm.head_dim)
    y, final = ssd_naive(xh, dt, A, Bm.reshape(B_, L, g, n),
                         Cm.reshape(B_, L, g, n), initial_state=state.ssd)
    y = y + params["D"][None, None, :, None] * xh
    y = (y.reshape(B_, L, di) * jax.nn.silu(z)).astype(x.dtype)
    # new conv window: the Kc-1 raw inputs ending at the last valid
    # position (per row) — for v valid tokens that window starts at
    # offset v into [carried | chunk]
    if token_mask is None:
        v = jnp.full((B_,), L, jnp.int32)
    else:
        v = token_mask.sum(1).astype(jnp.int32)
    widx = v[:, None] + jnp.arange(Kc - 1)[None, :]             # (B,Kc-1)
    conv_state = jnp.take_along_axis(cat, widx[..., None],
                                     axis=1).astype(state.conv.dtype)
    return y @ params["out_proj"], SSMState(conv_state, final)


def mamba2_decode(params, x, state: SSMState, ssm: SSMConfig, d_model):
    """One-token decode. x: (B,1,d) -> (B,1,d), new state."""
    B_ = x.shape[0]
    di = ssm.expand * d_model
    nh = di // ssm.head_dim
    g, n = ssm.n_groups, ssm.d_state
    zxbcdt = x[:, 0] @ params["in_proj"]                                 # (B, ·)
    z, xBC_raw, dt = jnp.split(zxbcdt, [di, di + di + 2 * g * n], axis=-1)
    # rolling conv window
    window = jnp.concatenate([state.conv, xBC_raw[:, None]], axis=1)     # (B,K,D)
    w = params["conv_w"]
    xBC = jax.nn.silu(jnp.einsum("bkd,kd->bd", window, w) + params["conv_b"])
    new_conv = window[:, 1:]
    xs, Bm, Cm = jnp.split(xBC, [di, di + g * n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])     # (B,nh)
    A = -jnp.exp(params["A_log"])
    xh = xs.reshape(B_, nh, ssm.head_dim)
    Bm = jnp.repeat(Bm.reshape(B_, g, n), nh // g, axis=1)
    Cm = jnp.repeat(Cm.reshape(B_, g, n), nh // g, axis=1)
    new_ssd, y = ssd_decode_step(state.ssd, xh, dt, A, Bm, Cm)
    y = y + params["D"][None, :, None] * xh
    y = (y.reshape(B_, di) * jax.nn.silu(z)).astype(x.dtype) @ params["out_proj"]
    return y[:, None], SSMState(new_conv, new_ssd)


def ssm_state_slice(state: SSMState, row) -> SSMState:
    """Value snapshot of one batch row of a period-stacked state.

    ``state`` arrays are (n_periods, B, ...) — the serving layout from
    ``transformer.init_caches``; the snapshot drops the batch axis.
    Exact: plain slices, no arithmetic, so snapshot -> restore is
    bit-identical (the state-pool preemption/prefix-cache guarantee)."""
    return SSMState(state.conv[:, row], state.ssd[:, row])


def ssm_state_restore(state: SSMState, snap: SSMState, row) -> SSMState:
    """Write a :func:`ssm_state_slice` snapshot back into batch ``row``."""
    return SSMState(state.conv.at[:, row].set(snap.conv.astype(state.conv.dtype)),
                    state.ssd.at[:, row].set(snap.ssd.astype(state.ssd.dtype)))


def ssm_state_zero_row(state: SSMState, row) -> SSMState:
    """Reset one batch row to the initial (zero) state — fresh-admission
    hygiene for recycled engine slots."""
    return SSMState(state.conv.at[:, row].set(jnp.zeros_like(state.conv[:, row])),
                    state.ssd.at[:, row].set(jnp.zeros_like(state.ssd[:, row])))


def init_ssm_state(batch, d_model, ssm: SSMConfig, dtype):
    di = ssm.expand * d_model
    nh = di // ssm.head_dim
    d_xBC = di + 2 * ssm.n_groups * ssm.d_state
    return SSMState(
        conv=jnp.zeros((batch, ssm.d_conv - 1, d_xBC), dtype),
        ssd=jnp.zeros((batch, nh, ssm.head_dim, ssm.d_state), jnp.float32),
    )
