"""Dense FFN blocks: SwiGLU, squared-ReLU, GELU."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import dense_init, activation_fn


def ffn_init(key, d_model, d_ff, activation, dtype):
    ks = jax.random.split(key, 3)
    p = {
        "w_up": dense_init(ks[0], d_model, d_ff, dtype),
        "w_down": dense_init(ks[1], d_ff, d_model, dtype),
    }
    if activation == "swiglu":
        p["w_gate"] = dense_init(ks[2], d_model, d_ff, dtype)
    return p


def ffn(params, x, activation):
    if activation == "swiglu":
        h = jax.nn.silu(x @ params["w_gate"]) * (x @ params["w_up"])
    else:
        h = activation_fn(activation)(x @ params["w_up"])
    return h @ params["w_down"]
