"""MoE FFN block.

Weight layout (stacked over experts — shardable on any axis):
  w_gate, w_up : (E, d_model, d_expert)       (w_gate only for swiglu)
  w_down       : (E, d_expert, d_model)

Execution is dispatched through the strategy registry
(``repro.core.strategy``): ``moe_block`` resolves an
:class:`ExecutionSpec` (or legacy ``impl`` string) to a registered
strategy — dense / capacity (single-device, implemented here), fse_dp
(``repro.core.fse_dp``), ep / tp (``repro.core.baselines``), or the
cross-family ``auto`` planner.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.core import gating
from repro.kernels import ops as kops
from .layers import dense_init
from .mlp import ffn_init, ffn


def moe_init(key, d_model, moe: MoEConfig, activation, dtype):
    ks = jax.random.split(key, 5)
    E, de = moe.num_experts, moe.d_expert
    p = {
        "router": gating.router_init(ks[0], d_model, E, dtype),
        "w_up": _stack_init(ks[1], E, d_model, de, dtype),
        "w_down": _stack_init(ks[2], E, de, d_model, dtype),
    }
    if activation == "swiglu":
        p["w_gate"] = _stack_init(ks[3], E, d_model, de, dtype)
    if moe.num_shared_experts:
        p["shared"] = ffn_init(ks[4], d_model, de * moe.num_shared_experts, activation, dtype)
    return p


def _stack_init(key, E, d_in, d_out, dtype):
    ks = jax.random.split(key, E)
    return jnp.stack([dense_init(k, d_in, d_out, dtype) for k in ks])


def _expert_act(params, xe, activation):
    """xe: (..., E-batched leading dims with x (..., d)) applied per expert.

    params w_*: (E, d, de). xe: (E, C, d) -> (E, C, d).
    """
    if activation == "swiglu":
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, params["w_gate"])) \
            * jnp.einsum("ecd,edf->ecf", xe, params["w_up"])
    else:
        from .layers import activation_fn
        h = activation_fn(activation)(jnp.einsum("ecd,edf->ecf", xe, params["w_up"]))
    return jnp.einsum("ecf,efd->ecd", h, params["w_down"])


# ---------------------------------------------------------------------------
# dense oracle — O(T·E) compute, exact
# ---------------------------------------------------------------------------

def moe_dense(params, x2d, routing, activation, schedule=None):
    """x2d: (T,d); returns (T,d). Computes all experts, weighted combine.

    A dynamic ``schedule`` reindexes the per-expert batch axis into
    trajectory order (outputs restored before the combine — values are
    bit-identical; only per-expert execution order changes)."""
    from repro.core import trajectory
    T, d = x2d.shape
    E = params["w_up"].shape[0]
    order = trajectory.resolve_order(
        schedule, lambda: gating.expert_token_counts(routing))
    xe = jnp.broadcast_to(x2d[None], (E, T, d))
    p = params if order is None else _reorder_experts(params, order)
    ye = _expert_act(p, xe, activation)               # (E,T,d)
    if order is not None:
        ye = trajectory.restore_order(order, ye)
    return jnp.einsum("te,etd->td", routing.combine, ye)


# ---------------------------------------------------------------------------
# capacity dispatch — Switch-style, efficient on one device
# ---------------------------------------------------------------------------

def capacity_of(T, moe: MoEConfig):
    return moe.capacity_rows(T)


def dispatch_masks(routing, T, E, C):
    """Build (T,E,C) dispatch one-hot + (T,E,C) combine weights.

    Tokens beyond an expert's capacity C are dropped (standard EP
    baseline semantics — the paper's EP baseline also has finite
    per-die buffering).
    """
    onehot = jax.nn.one_hot(routing.indices, E, dtype=jnp.int32).sum(1)   # (T,E) 0/1
    pos = jnp.cumsum(onehot, axis=0) * onehot - 1                          # position in expert queue
    keep = (pos >= 0) & (pos < C)
    pos = jnp.clip(pos, 0, C - 1)
    dispatch = jax.nn.one_hot(pos, C) * keep[..., None]                    # (T,E,C)
    combine = dispatch * routing.combine[..., None]                        # (T,E,C)
    return dispatch, combine


def _expert_ffn(params, xe, activation):
    """(E,C,d) -> (E,C,d) fp32 via the ``kernels.ops.streamed_moe_autotuned``
    dispatch layer (Pallas micro-slice kernel with planner-chosen tiles, or
    the jnp oracle under ``use_kernels(False)``)."""
    return kops.streamed_moe_autotuned(xe, params.get("w_gate"),
                                       params["w_up"], params["w_down"],
                                       activation)


def _reorder_experts(params, order):
    """Expert-stacked weight views in trajectory order (router/shared
    untouched — they are not expert-indexed)."""
    out = dict(params)
    for k in ("w_gate", "w_up", "w_down"):
        if k in params:
            out[k] = jnp.take(params[k], order, axis=0)
    return out


def moe_capacity(params, x2d, routing, moe: MoEConfig, activation,
                 schedule=None):
    """Capacity dispatch -> grouped expert FFN -> combine.

    The route stage happened upstream (``routing``); a dynamic
    ``schedule`` (``repro.core.trajectory``) reindexes the dispatched
    rows and weight stacks into trajectory order for the expert FFN and
    restores canonical order before the combine, so outputs are
    bit-identical to the static path."""
    from repro.core import trajectory
    T, d = x2d.shape
    E = moe.num_experts
    C = capacity_of(T, moe)
    order = trajectory.resolve_order(
        schedule, lambda: gating.expert_token_counts(routing))
    p = params if order is None else _reorder_experts(params, order)
    if sorted_dispatch_enabled():
        idx, wts = dispatch_tables(routing, T, E, C)
        g_idx = idx if order is None else jnp.take(idx, order, axis=0)
        xe = gather_dispatch(x2d, g_idx)                                   # (E,C,d)
        ye = _expert_ffn(p, xe, activation)
        if order is not None:
            ye = trajectory.restore_order(order, ye)
        return scatter_combine(ye, idx, wts, T)
    dispatch, combine = dispatch_masks(routing, T, E, C)
    xe = jnp.einsum("tec,td->ecd", dispatch.astype(x2d.dtype), x2d)        # (E,C,d)
    if order is not None:
        (xe,) = trajectory.apply_order(order, xe)
    ye = _expert_ffn(p, xe, activation)                                    # (E,C,d) fp32
    if order is not None:
        ye = trajectory.restore_order(order, ye)
    return jnp.einsum("tec,ecd->td", combine.astype(jnp.float32),
                      ye).astype(x2d.dtype)


# ---------------------------------------------------------------------------
# hybrid two-tier dispatch — hot prefix on the fast array, cold tail near
# memory.  The tier split is placement only: the expert axis is a pure
# batch axis of the grouped FFN, so computing it as two groups (and on
# real two-tier hardware, two *places*) is bit-identical to one group.
# ---------------------------------------------------------------------------


def _expert_ffn_tiered(params, xe, activation, hot):
    """(E,C,d) -> (E,C,d) fp32 computed as a hot prefix + cold tail.

    ``hot`` is the fast-tier expert count H over the (already
    trajectory-ordered) expert axis: rows ``[:H]`` model the chiplet
    array's streamed flow, rows ``[H:]`` the near-memory tier.  Each
    group runs the same grouped-FFN dispatch layer; per-expert compute
    is independent and the kernel's tile choice is E-invariant, so the
    split never changes values (tests/test_hybrid.py)."""
    E = xe.shape[0]
    H = max(0, min(int(hot), E))
    if H in (0, E):
        return _expert_ffn(params, xe, activation)

    def _slice(a, b):
        return {k: (v[a:b] if k in ("w_gate", "w_up", "w_down") else v)
                for k, v in params.items()}

    y_hot = _expert_ffn(_slice(0, H), xe[:H], activation)
    y_cold = _expert_ffn(_slice(H, E), xe[H:], activation)
    return jnp.concatenate([y_hot, y_cold], axis=0)


def moe_hybrid(params, x2d, routing, moe: MoEConfig, activation, *,
               hot_experts, schedule=None):
    """Capacity dispatch -> two-tier grouped FFN -> combine.

    Experts are reindexed into load-descending order (the host EMA load
    when a schedule carries one, else this call's own routing counts,
    derived in-graph so the fused serving steps never retrace), the
    hottest ``hot_experts`` form the fast-tier prefix, and canonical
    order is restored before the combine — outputs are bit-identical to
    ``moe_capacity`` on the same routing."""
    from repro.core import trajectory
    T, d = x2d.shape
    E = moe.num_experts
    C = capacity_of(T, moe)
    if schedule is not None and schedule.load is not None:
        import numpy as np
        order = jnp.asarray(
            np.argsort(-np.asarray(schedule.load), kind="stable"),
            jnp.int32)
    else:
        counts = gating.expert_token_counts(routing)
        order = jnp.argsort(-jnp.asarray(counts), stable=True) \
            .astype(jnp.int32)
    p = _reorder_experts(params, order)
    if sorted_dispatch_enabled():
        idx, wts = dispatch_tables(routing, T, E, C)
        xe = gather_dispatch(x2d, jnp.take(idx, order, axis=0))     # (E,C,d)
        ye = _expert_ffn_tiered(p, xe, activation, hot_experts)
        ye = trajectory.restore_order(order, ye)
        return scatter_combine(ye, idx, wts, T)
    dispatch, combine = dispatch_masks(routing, T, E, C)
    xe = jnp.einsum("tec,td->ecd", dispatch.astype(x2d.dtype), x2d)  # (E,C,d)
    (xe,) = trajectory.apply_order(order, xe)
    ye = _expert_ffn_tiered(p, xe, activation, hot_experts)          # fp32
    ye = trajectory.restore_order(order, ye)
    return jnp.einsum("tec,ecd->td", combine.astype(jnp.float32),
                      ye).astype(x2d.dtype)


# ---------------------------------------------------------------------------
# sorted dispatch — gather/scatter instead of one-hot einsums
#
# The one-hot dispatch/combine einsums cost O(T·E·C·d) MXU flops (3-4x the
# useful expert GEMMs for fine-grained MoEs); sorting token-choices by
# expert and using gather/scatter moves the same data with zero matmul
# flops.  Enabled via ``use_sorted_dispatch`` (a §Perf hillclimb knob; the
# one-hot path stays as the paper-faithful capacity baseline + oracle).
# ---------------------------------------------------------------------------

import contextlib
import contextvars

_SORTED = contextvars.ContextVar("repro_sorted_dispatch", default=False)


@contextlib.contextmanager
def use_sorted_dispatch(enabled: bool = True):
    tok = _SORTED.set(enabled)
    try:
        yield
    finally:
        _SORTED.reset(tok)


def sorted_dispatch_enabled() -> bool:
    from repro.parallel import meshctx
    return _SORTED.get() or meshctx.opt_enabled("sorted")


def dispatch_tables(routing, T, E, C):
    """(idx (E,C) int32 token ids [T = padding sentinel], wts (E,C))."""
    k = routing.indices.shape[1]
    e_flat = routing.indices.reshape(-1)                       # (T*k,)
    t_flat = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
    w_flat = routing.weights.reshape(-1)
    order = jnp.argsort(e_flat, stable=True)
    e_s, t_s, w_s = e_flat[order], t_flat[order], w_flat[order]
    # position within the expert group (first occurrence offsets)
    start = jnp.searchsorted(e_s, e_s, side="left")
    pos = jnp.arange(T * k, dtype=jnp.int32) - start.astype(jnp.int32)
    # overflow entries keep pos >= C and fall out via mode="drop" (clipping
    # them would clobber the legitimate occupant of slot C-1)
    idx = jnp.full((E, C), T, jnp.int32)
    idx = idx.at[e_s, pos].set(t_s, mode="drop")
    wts = jnp.zeros((E, C), w_s.dtype)
    wts = wts.at[e_s, pos].set(w_s, mode="drop")
    return idx, wts


def gather_dispatch(x2d, idx):
    """x2d: (T,d); idx: (E,C) -> (E,C,d) with zero rows for padding."""
    xpad = jnp.concatenate([x2d, jnp.zeros((1, x2d.shape[1]), x2d.dtype)])
    return xpad[idx]


def scatter_combine(ye, idx, wts, T):
    """ye: (E,C,d) -> (T,d) weighted scatter-add."""
    d = ye.shape[-1]
    contrib = (ye.astype(jnp.float32) * wts[..., None].astype(jnp.float32))
    y = jnp.zeros((T + 1, d), jnp.float32)
    y = y.at[idx.reshape(-1)].add(contrib.reshape(-1, d), mode="drop")
    return y[:T]


# ---------------------------------------------------------------------------
# block entry point
# ---------------------------------------------------------------------------

def moe_block(params, x, moe: MoEConfig, activation, *, impl=None, spec=None,
              phase=None, layer=None, mesh_axis="model", return_aux=False,
              routing=None, schedule=None):
    """x: (B,S,d) or (T,d); thin lookup into the execution-strategy
    registry (``repro.core.strategy``).

    ``spec`` is anything :meth:`ExecutionSpec.coerce` accepts (a spec, a
    strategy name, a dict); ``impl`` is the legacy string knob, kept as
    an alias.  With neither, ``moe.impl`` names the default strategy.
    ``phase`` ('train' | 'prefill' | 'decode') and ``layer`` select the
    spec's per-phase / per-layer overrides.  Distributed strategies
    (fse_dp / ep / tp) route *inside* shard_map on local tokens and
    return a pmean'd aux loss; single-device strategies route globally.

    Pipeline inputs: ``routing`` pre-computes the route stage (e.g. the
    serving engine's gate pass — single-device strategies only);
    ``schedule`` pre-computes the schedule stage (a host-built
    ``trajectory.Schedule``).  With neither, the spec's ``schedule``
    knob still applies: ``"dynamic"`` makes every strategy derive its
    expert trajectory in-graph from its own routing counts.
    """
    from repro.core import strategy as strat
    from repro.core import trajectory
    sp = strat.ExecutionSpec.coerce(spec if spec is not None else impl,
                                    default=moe.impl)
    name = sp.resolve(phase=phase, layer=layer)
    if schedule is None and sp.schedule == "dynamic":
        schedule = trajectory.DYNAMIC
    shape = x.shape
    if x.ndim == 2:
        x = x[None]
    with sp.scope():
        y, aux = strat.get_strategy(name).execute(params, x, moe, activation,
                                                  axis=mesh_axis,
                                                  routing=routing,
                                                  schedule=schedule)
    if moe.num_shared_experts:
        y = y + ffn(params["shared"], x, activation)
    y = y.reshape(shape)
    if return_aux:
        return y, aux
    return y
