"""Unified model API — family dispatch for init / train loss / prefill / decode.

This is the surface the launcher, trainer, serving engine, smoke tests
and dry-run all use.  Batches are dicts (see ``input_specs`` in
``repro.launch.specs`` for the exact keys per shape cell).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from . import transformer, whisper


def init_params(key, cfg: ModelConfig):
    if cfg.is_encoder_decoder:
        return whisper.init_encdec(key, cfg)
    return transformer.init_lm(key, cfg)


def _xent(logits, labels, ignore_label=-1):
    """Mean token cross-entropy in fp32; labels==ignore_label are masked.

    The gold logit is picked with an iota-compare (not a gather) so a
    vocab-sharded logits tensor never needs an all-gather."""
    lf = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(lf, axis=-1)
    iota = jax.lax.broadcasted_iota(jnp.int32, lf.shape, lf.ndim - 1)
    gold = jnp.sum(jnp.where(iota == labels[..., None], lf, 0.0), axis=-1)
    nll = logz - gold
    mask = (labels != ignore_label).astype(jnp.float32)
    return jnp.sum(nll * mask), jnp.sum(mask)


CE_CHUNK = 512


def fused_xent(h, head, labels, ignore_label=-1):
    """Fused unembed + CE, chunked over the sequence: full (B,S,V) f32
    logits are never materialized (peak = one chunk, recomputed in bwd).
    A non-divisible tail is handled as one extra direct chunk."""
    B, S, d = h.shape

    @jax.checkpoint
    def one(args):
        hb, lb = args
        return _xent(hb @ head, lb, ignore_label)

    if S <= CE_CHUNK:
        tot, cnt = _xent(h @ head, labels, ignore_label)
        return tot / jnp.maximum(cnt, 1.0)
    nc = S // CE_CHUNK
    main = nc * CE_CHUNK
    hc = jnp.moveaxis(h[:, :main].reshape(B, nc, CE_CHUNK, d), 1, 0)
    lc = jnp.moveaxis(labels[:, :main].reshape(B, nc, CE_CHUNK), 1, 0)
    tot, cnt = jax.lax.map(one, (hc, lc))
    tot, cnt = jnp.sum(tot), jnp.sum(cnt)
    if main < S:
        t2, c2 = one((h[:, main:], labels[:, main:]))
        tot, cnt = tot + t2, cnt + c2
    return tot / jnp.maximum(cnt, 1.0)


def _head(params):
    head = params.get("lm_head")
    return head if head is not None else params["embed"].T


def loss_fn(params, batch, cfg: ModelConfig, *, spec=None, remat=False,
            use_flash=False, unshard=False):
    """Training loss (CE + MoE aux). Returns (loss, metrics)."""
    if cfg.is_encoder_decoder:
        memory = whisper.encode(params, batch["frames"], cfg, remat=remat)
        h = whisper.decode_train(params, batch["tokens"], memory, cfg,
                                 remat=remat, return_hidden=True)
        ce = fused_xent(h, params["embed"].T, batch["labels"])
        return ce, {"ce": ce, "aux": jnp.zeros(())}
    prefix = batch.get("prefix_embeds")
    h, aux = transformer.forward(params, batch["tokens"], cfg,
                                 prefix_embeds=prefix, spec=spec,
                                 remat=remat, use_flash=use_flash,
                                 unshard=unshard, return_hidden=True)
    labels = batch["labels"]
    if prefix is not None:  # VLM: loss only on the text positions
        h = h[:, prefix.shape[1]:]
    ce = fused_xent(h, _head(params), labels)
    coef = cfg.moe.aux_loss_coef if cfg.moe else 0.0
    return ce + coef * aux, {"ce": ce, "aux": aux}


def prefill_fn(params, batch, cfg: ModelConfig, max_seq: int, *, spec=None):
    """Prompt processing -> (logits, caches)."""
    if cfg.is_encoder_decoder:
        memory = whisper.encode(params, batch["frames"], cfg)
        caches = whisper.init_decode_caches(params, memory, cfg,
                                            batch["frames"].shape[0], max_seq)
        logits = whisper.decode_train(params, batch["tokens"], memory, cfg)
        return logits, caches
    return transformer.prefill(params, batch["tokens"], cfg, max_seq,
                               prefix_embeds=batch.get("prefix_embeds"),
                               spec=spec)


def prefill_chunk_fn(params, tokens, caches, cache_len, cfg: ModelConfig, *,
                     spec=None, token_mask=None, return_hidden=False,
                     page_table=None):
    """Append a K-token prompt chunk to existing decode caches.

    The continuous-batching engine's admission path: prompts are
    processed ``chunk_tokens`` at a time piggybacked on the decode
    batch, so admission never blocks an iteration.  Returns
    (logits (B,K,V) — or final hidden states with ``return_hidden``,
    new_caches, per-layer expert counts) — see
    ``transformer.prefill_chunk``.
    """
    if cfg.is_encoder_decoder:
        raise NotImplementedError("chunked prefill serves LM-family models")
    return transformer.prefill_chunk(params, tokens, caches, cache_len, cfg,
                                     spec=spec, token_mask=token_mask,
                                     return_hidden=return_hidden,
                                     page_table=page_table)


# ---------------------------------------------------------------------------
# serving decode segments (LM-family only)
#
# The per-layer sub-steps the serving engine composes: the legacy eager
# loop calls them one layer at a time, the fused mega-step engine
# (repro.serving.megastep) traces the same functions into one compiled
# segment per MoE-boundary span.  Re-exported here so serving code stays
# on the model-API surface.
# ---------------------------------------------------------------------------

decode_embed_merge = transformer.decode_embed_merge
decode_mixer = transformer.decode_mixer
decode_route = transformer.decode_route
decode_moe_exec = transformer.decode_moe_exec
decode_ffn = transformer.decode_ffn
decode_span = transformer.decode_span
decode_logits = transformer.decode_logits


def decode_fn(params, token, caches, cache_len, cfg: ModelConfig, *,
              spec=None, unshard=False):
    """One decode step -> (logits, new caches)."""
    if cfg.is_encoder_decoder:
        return whisper.decode_step(params, token, caches, cache_len, cfg)
    return transformer.decode_step(params, token, caches, cache_len, cfg,
                                   spec=spec, unshard=unshard)


def init_decode_caches(params, cfg: ModelConfig, batch: int, max_seq: int,
                       memory_len: int = 1500):
    """Fresh (empty) decode caches for serve_step lowering."""
    if cfg.is_encoder_decoder:
        mem = jnp.zeros((batch, memory_len, cfg.d_model), jnp.dtype(cfg.dtype))
        return whisper.init_decode_caches(params, mem, cfg, batch, max_seq)
    return transformer.init_caches(cfg, batch, max_seq)
