"""GQA attention: full causal (train / prefill), cross, and KV-cache decode.

The XLA path is written so the SPMD partitioner can shard heads over the
``model`` axis and batch over ``(pod, data)``.  A Pallas flash-attention
kernel (``repro.kernels.flash_attention``) is available behind
``use_flash`` for the causal path.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .layers import apply_rope, dense_init


class KVCache(NamedTuple):
    k: jnp.ndarray          # (B, S, n_kv, hd)
    v: jnp.ndarray          # (B, S, n_kv, hd)


def attn_init(key, d_model, n_heads, n_kv, head_dim, dtype):
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], d_model, n_heads * head_dim, dtype),
        "wk": dense_init(ks[1], d_model, n_kv * head_dim, dtype),
        "wv": dense_init(ks[2], d_model, n_kv * head_dim, dtype),
        "wo": dense_init(ks[3], n_heads * head_dim, d_model, dtype),
    }


def _split_heads(x, n, hd):
    return x.reshape(*x.shape[:-1], n, hd)


def _repeat_kv(k, n_heads):
    """(B,S,n_kv,hd) -> (B,S,n_heads,hd) by group broadcast."""
    n_kv = k.shape[-2]
    if n_kv == n_heads:
        return k
    rep = n_heads // n_kv
    return jnp.repeat(k, rep, axis=-2)


def _sdpa(q, k, v, mask=None):
    """q:(B,Sq,H,hd) k,v:(B,Sk,H,hd); fp32 softmax."""
    hd = q.shape[-1]
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(hd))
    if mask is not None:
        scores = jnp.where(mask, scores, jnp.float32(-1e30))
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def causal_mask(sq: int, sk: int):
    # query i attends to keys j <= i + (sk - sq)
    i = jnp.arange(sq)[:, None]
    j = jnp.arange(sk)[None, :]
    return (j <= i + (sk - sq))[None, None]  # (1,1,Sq,Sk)


# chunk the query dim above this length — keeps live attention scores
# O(chunk·Sk) instead of O(Sq·Sk) (the pure-XLA flash-equivalent used by
# the 32k prefill cells; the Pallas kernel covers the TPU fast path)
CHUNKED_THRESHOLD = 4096
QUERY_CHUNK = 1024


def _chunked_sdpa(q, k, v, *, causal: bool):
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    c = QUERY_CHUNK
    nq = Sq // c
    qb = jnp.moveaxis(q.reshape(B, nq, c, H, hd), 1, 0)      # (nq,B,c,H,hd)

    def blk(args):
        i, qi = args
        s = jnp.einsum("bqhd,bkhd->bhqk", qi, k).astype(jnp.float32)
        s = s / jnp.sqrt(jnp.float32(hd))
        if causal:
            qpos = i * c + jnp.arange(c)[:, None]
            kpos = jnp.arange(Sk)[None, :]
            s = jnp.where((kpos <= qpos + (Sk - Sq))[None, None], s,
                          jnp.float32(-1e30))
        p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
        return jnp.einsum("bhqk,bkhd->bqhd", p, v)

    out = jax.lax.map(blk, (jnp.arange(nq), qb))             # (nq,B,c,H,hd)
    return jnp.moveaxis(out, 0, 1).reshape(B, Sq, H, hd)


def attention(params, x, *, n_heads, n_kv, head_dim, rope_theta,
              positions=None, causal=True, use_flash=False):
    """Full self-attention. x: (B,S,d)."""
    from repro.parallel import meshctx
    if meshctx.opt_enabled("sp_attn"):
        # explicit SP entry: one all-gather of the (seq-sharded) input
        # instead of partitioner-chosen activation reshards per matmul
        from repro.parallel.sharding import constrain_batch_only
        x = constrain_batch_only(x)
    B, S, _ = x.shape
    q = _split_heads(x @ params["wq"], n_heads, head_dim)
    k = _split_heads(x @ params["wk"], n_kv, head_dim)
    v = _split_heads(x @ params["wv"], n_kv, head_dim)
    if rope_theta:
        if positions is None:
            positions = jnp.arange(S)[None, :]
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)
    if use_flash and causal:
        from repro.kernels import flash_ops
        out = flash_ops.flash_attention(q, _repeat_kv(k, n_heads), _repeat_kv(v, n_heads))
    else:
        kf = _repeat_kv(k, n_heads)
        vf = _repeat_kv(v, n_heads)
        if S >= CHUNKED_THRESHOLD and S % QUERY_CHUNK == 0:
            out = _chunked_sdpa(q, kf, vf, causal=causal)
        else:
            mask = causal_mask(S, S) if causal else None
            out = _sdpa(q, kf, vf, mask)
    return out.reshape(B, S, n_heads * head_dim) @ params["wo"]


def cross_attention(params, x, memory, *, n_heads, n_kv, head_dim):
    """x: (B,Sq,d) attends to memory (B,Sk,d). No RoPE, no mask."""
    B, Sq, _ = x.shape
    q = _split_heads(x @ params["wq"], n_heads, head_dim)
    k = _split_heads(memory @ params["wk"], n_kv, head_dim)
    v = _split_heads(memory @ params["wv"], n_kv, head_dim)
    out = _sdpa(q, _repeat_kv(k, n_heads), _repeat_kv(v, n_heads))
    return out.reshape(B, Sq, n_heads * head_dim) @ params["wo"]


# ---------------------------------------------------------------------------
# Decode path (one new token against a KV cache)
# ---------------------------------------------------------------------------

def init_kv_cache(batch, seq, n_kv, head_dim, dtype):
    z = jnp.zeros((batch, seq, n_kv, head_dim), dtype)
    return KVCache(z, z)


def attention_decode(params, x, cache: KVCache, cache_len, *,
                     n_heads, n_kv, head_dim, rope_theta,
                     update_cache: bool = True):
    """Single-token decode.

    x: (B,1,d); cache k/v: (B,S,n_kv,hd); cache_len: (B,) current lengths
    (the new token is written at index ``cache_len`` when it fits).
    Returns (out (B,1,d), new_cache).

    For the assigned ``decode_*`` shape cells the cache is *full*
    (KV of seq_len, cache_len == S): the new K/V then contributes via a
    one-step sliding update at the last slot.
    """
    B, _, _ = x.shape
    S = cache.k.shape[1]
    from repro.parallel.sharding import constrain_batch_only
    q = _split_heads(x @ params["wq"], n_heads, head_dim)      # (B,1,H,hd)
    k_new = _split_heads(x @ params["wk"], n_kv, head_dim)     # (B,1,kv,hd)
    v_new = _split_heads(x @ params["wv"], n_kv, head_dim)
    if rope_theta:
        pos = cache_len[:, None]                                # (B,1)
        q = apply_rope(q, pos, rope_theta)
        k_new = apply_rope(k_new, pos, rope_theta)
    # single-token tensors stay model-replicated so the (huge) KV cache
    # keeps its sequence-parallel sharding end to end
    q = constrain_batch_only(q)
    k_new = constrain_batch_only(k_new)
    v_new = constrain_batch_only(v_new)

    if update_cache:
        idx = jnp.minimum(cache_len, S - 1)                     # (B,)
        from repro.parallel import meshctx as _mc
        if _mc.opt_enabled("scatter_cache"):
            rows = jnp.arange(B)
            k = cache.k.at[rows, idx].set(k_new[:, 0].astype(cache.k.dtype))
            v = cache.v.at[rows, idx].set(v_new[:, 0].astype(cache.v.dtype))
        else:
            onehot = jax.nn.one_hot(idx, S, dtype=cache.k.dtype)    # (B,S)
            k = cache.k * (1 - onehot)[..., None, None] + onehot[..., None, None] * k_new
            v = cache.v * (1 - onehot)[..., None, None] + onehot[..., None, None] * v_new
    else:
        k, v = cache.k, cache.v

    from repro.parallel.sharding import constrain_kv_seq
    kf = constrain_kv_seq(_repeat_kv(k, n_heads))               # (B,S,H,hd)
    vf = constrain_kv_seq(_repeat_kv(v, n_heads))
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, kf).astype(jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(head_dim))
    valid = (jnp.arange(S)[None, :] <= jnp.minimum(cache_len, S - 1)[:, None])
    scores = jnp.where(valid[:, None, None, :], scores, jnp.float32(-1e30))
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, vf)
    out = out.reshape(B, 1, n_heads * head_dim) @ params["wo"]
    return out, KVCache(k, v)


def attention_append(params, x, cache: KVCache, cache_len, *,
                     n_heads, n_kv, head_dim, rope_theta, token_mask=None):
    """Chunked-prefill step: append a K-token chunk to the KV cache.

    x: (B,K,d); cache k/v: (B,S,n_kv,hd); cache_len: (B,) tokens already
    cached per row.  ``token_mask`` (B,K) marks the valid chunk prefix
    per row (rows may be mid-prompt at different depths, and the last
    chunk of a prompt is usually partial): invalid positions neither
    write the cache nor become visible to any valid query, so a row
    whose mask is all-False passes through bit-untouched.

    Each valid token lands at absolute position ``cache_len + i`` and
    attends causally over everything at or before it — exactly the keys
    the monolithic ``prefill`` path would give it.  Returns
    (out (B,K,d), new cache).
    """
    B, K, _ = x.shape
    S = cache.k.shape[1]
    q = _split_heads(x @ params["wq"], n_heads, head_dim)      # (B,K,H,hd)
    k_new = _split_heads(x @ params["wk"], n_kv, head_dim)
    v_new = _split_heads(x @ params["wv"], n_kv, head_dim)
    pos = cache_len[:, None] + jnp.arange(K)[None, :]           # (B,K)
    if rope_theta:
        q = apply_rope(q, pos, rope_theta)
        k_new = apply_rope(k_new, pos, rope_theta)
    if token_mask is None:
        token_mask = jnp.ones((B, K), bool)
    # masked positions are steered out of range and dropped by the scatter
    idx = jnp.where(token_mask, pos, S)
    rows = jnp.arange(B)[:, None]
    k = cache.k.at[rows, idx].set(k_new.astype(cache.k.dtype), mode="drop")
    v = cache.v.at[rows, idx].set(v_new.astype(cache.v.dtype), mode="drop")

    kf = _repeat_kv(k, n_heads)                                 # (B,S,H,hd)
    vf = _repeat_kv(v, n_heads)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, kf).astype(jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(head_dim))
    valid = (jnp.arange(S)[None, None, :]
             <= jnp.minimum(pos, S - 1)[:, :, None])            # (B,K,S)
    scores = jnp.where(valid[:, None], scores, jnp.float32(-1e30))
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, vf)
    return out.reshape(B, K, n_heads * head_dim) @ params["wo"], KVCache(k, v)


# ---------------------------------------------------------------------------
# Paged KV cache (serving state pool)
# ---------------------------------------------------------------------------
#
# The serving engine stores attention KV in fixed-size pages owned by a
# pool (``repro.serving.statepool``) instead of one dense max_ctx slab
# per slot: ``pages.k/v`` are (P, page_size, n_kv, hd) physical pages and
# ``page_table`` (B, NP) maps each slot's logical page index to a
# physical page.  The paged variants below gather the table into a dense
# per-slot view, run the *same* dense attention math (so paged and dense
# agree bitwise on equal values), and scatter only the newly written
# positions back — shared pages (prefix-cache hits) are never written,
# because writes only land at positions >= the shared prefix length and
# partial tail pages are copy-on-write at attach time.


def init_paged_kv_cache(num_pages, page_size, n_kv, head_dim, dtype):
    z = jnp.zeros((num_pages, page_size, n_kv, head_dim), dtype)
    return KVCache(z, z)


def gather_pages(pages: KVCache, page_table) -> KVCache:
    """Dense per-slot view (B, NP*page_size, n_kv, hd) of the paged pool.

    Pure gather: positions beyond a slot's cache_len read whatever the
    physical page holds, exactly like the dense cache's unwritten tail —
    both are masked out of the softmax by the validity mask."""
    ps = pages.k.shape[1]
    B, NP = page_table.shape

    def dense(a):
        return a[page_table].reshape(B, NP * ps, *a.shape[2:])

    return KVCache(dense(pages.k), dense(pages.v))


def attention_decode_paged(params, x, pages: KVCache, page_table, cache_len,
                           *, n_heads, n_kv, head_dim, rope_theta, row_mask):
    """Single-token decode against the paged pool.

    Same math as :func:`attention_decode` on the gathered dense view;
    the new token's K/V is then scattered into the slot's tail page at
    ``(page_table[b, pos // ps], pos % ps)``.  ``row_mask`` (B,) marks
    rows that actually advance: masked rows are steered to the
    out-of-range offset ``ps`` and dropped, so an idle slot's (possibly
    stale) table row is never written through."""
    B = x.shape[0]
    ps = pages.k.shape[1]
    S = page_table.shape[1] * ps
    dense = gather_pages(pages, page_table)
    out, nd = attention_decode(params, x, dense, cache_len,
                               n_heads=n_heads, n_kv=n_kv, head_dim=head_dim,
                               rope_theta=rope_theta, update_cache=True)
    rows = jnp.arange(B)
    idx = jnp.minimum(cache_len, S - 1)                     # (B,)
    k_new = nd.k[rows, idx]                                 # (B, n_kv, hd)
    v_new = nd.v[rows, idx]
    phys = page_table[rows, idx // ps]                      # (B,)
    off = jnp.where(jnp.asarray(row_mask), idx % ps, ps)    # masked -> drop
    k = pages.k.at[phys, off].set(k_new.astype(pages.k.dtype), mode="drop")
    v = pages.v.at[phys, off].set(v_new.astype(pages.v.dtype), mode="drop")
    return out, KVCache(k, v)


def attention_append_paged(params, x, pages: KVCache, page_table, cache_len,
                           *, n_heads, n_kv, head_dim, rope_theta,
                           token_mask=None):
    """Chunked-prefill append against the paged pool.

    Same math as :func:`attention_append` on the gathered dense view;
    each valid chunk position ``pos = cache_len + i`` is scattered into
    ``(page_table[b, pos // ps], pos % ps)``; masked positions drop."""
    B, K, _ = x.shape
    ps = pages.k.shape[1]
    NP = page_table.shape[1]
    S = NP * ps
    if token_mask is None:
        token_mask = jnp.ones((B, K), bool)
    dense = gather_pages(pages, page_table)
    out, nd = attention_append(params, x, dense, cache_len,
                               n_heads=n_heads, n_kv=n_kv, head_dim=head_dim,
                               rope_theta=rope_theta, token_mask=token_mask)
    rows = jnp.arange(B)[:, None]
    pos = cache_len[:, None] + jnp.arange(K)[None, :]       # (B,K)
    safe = jnp.minimum(pos, S - 1)
    k_new = nd.k[rows, safe]                                # (B,K,n_kv,hd)
    v_new = nd.v[rows, safe]
    phys = page_table[rows, safe // ps]                     # (B,K)
    off = jnp.where(token_mask, pos % ps, ps)               # masked -> drop
    k = pages.k.at[phys, off].set(k_new.astype(pages.k.dtype), mode="drop")
    v = pages.v.at[phys, off].set(v_new.astype(pages.v.dtype), mode="drop")
    return out, KVCache(k, v)


def prefill_kv(params, x, *, n_kv, head_dim, rope_theta, positions=None):
    """Compute the cache entries for a full prompt (used by prefill_step)."""
    B, S, _ = x.shape
    k = _split_heads(x @ params["wk"], n_kv, head_dim)
    v = _split_heads(x @ params["wv"], n_kv, head_dim)
    if rope_theta:
        if positions is None:
            positions = jnp.arange(S)[None, :]
        k = apply_rope(k, positions, rope_theta)
    return KVCache(k, v)
