"""Decoder-only LM assembly (dense / MoE / SSM / hybrid / VLM-prefix).

Layers are grouped into the smallest repeating *period* of identical
structure (1 for homogeneous stacks; 8 for Jamba's 1:7 attn:ssm
interleave with MoE every 2nd layer) and scanned over periods with
slot-wise stacked parameters.  This keeps the lowered HLO size
O(period) instead of O(num_layers) — essential for the 96-layer
nemotron-4-340b dry-run — while supporting heterogeneous layer plans.

Caches (KV / SSM state) are carried through the same scan as per-period
xs/ys so decode works for every family.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from . import attention as attn_mod
from . import mamba2 as ssm_mod
from . import moe as moe_mod
from .layers import embed_init, norm_init, apply_norm
from .mlp import ffn_init, ffn


# ---------------------------------------------------------------------------
# layer plan
# ---------------------------------------------------------------------------

def period_plan(cfg: ModelConfig):
    """Smallest p dividing num_layers with kinds[i] == kinds[i mod p]."""
    kinds = list(zip(cfg.layer_kinds(), cfg.ffn_kinds()))
    L = cfg.num_layers
    for p in range(1, L + 1):
        if L % p == 0 and all(kinds[i] == kinds[i % p] for i in range(L)):
            return p, kinds[:p]
    return L, kinds


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _slot_init(key, cfg: ModelConfig, mixer: str, ffn_kind: str):
    ks = jax.random.split(key, 4)
    slot: dict = {"norm1": norm_init(cfg.norm, cfg.d_model)}
    if mixer == "attn":
        slot["attn"] = attn_mod.attn_init(
            ks[0], cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
            cfg.resolved_head_dim, jnp.dtype(cfg.dtype))
    else:
        slot["ssm"] = ssm_mod.mamba2_init(ks[0], cfg.d_model, cfg.ssm, jnp.dtype(cfg.dtype))
    if ffn_kind != "none":
        slot["norm2"] = norm_init(cfg.norm, cfg.d_model)
        if ffn_kind == "moe":
            slot["moe"] = moe_mod.moe_init(ks[1], cfg.d_model, cfg.moe,
                                           cfg.activation, jnp.dtype(cfg.dtype))
        else:
            slot["ffn"] = ffn_init(ks[1], cfg.d_model, cfg.d_ff,
                                   cfg.activation, jnp.dtype(cfg.dtype))
    return slot


def init_lm(key, cfg: ModelConfig):
    p, plan = period_plan(cfg)
    n_periods = cfg.num_layers // p
    ks = jax.random.split(key, n_periods * p + 3)
    dtype = jnp.dtype(cfg.dtype)
    periods = []
    for s, (mixer, ffn_kind) in enumerate(plan):
        per = [_slot_init(ks[c * p + s], cfg, mixer, ffn_kind) for c in range(n_periods)]
        periods.append(jax.tree.map(lambda *xs: jnp.stack(xs), *per))
    params = {
        "embed": embed_init(ks[-1], cfg.vocab_size, cfg.d_model, dtype),
        "final_norm": norm_init(cfg.norm, cfg.d_model),
        "periods": tuple(periods),
    }
    if not cfg.tie_embeddings:
        from .layers import dense_init
        params["lm_head"] = dense_init(ks[-2], cfg.d_model, cfg.vocab_size, dtype)
    return params


# ---------------------------------------------------------------------------
# slot application
# ---------------------------------------------------------------------------

def _coerce_spec(spec):
    """Accept None / strategy name / dict / ExecutionSpec (see
    ``repro.core.strategy``); None keeps the config default."""
    if spec is None:
        return None
    from repro.core.strategy import ExecutionSpec
    return ExecutionSpec.coerce(spec)


def _needs_unroll(spec) -> bool:
    """Per-layer strategy overrides need a different lowering per
    period, so the scan-over-periods must unroll into a Python loop."""
    return spec is not None and bool(spec.layer_overrides)


def _apply_slot_full(slot, x, cfg: ModelConfig, mixer, ffn_kind, *,
                     positions=None, spec=None, phase="train", layer=None,
                     use_flash=False):
    """Full-sequence forward for one layer slot. Returns (x, aux)."""
    h = apply_norm(cfg.norm, slot["norm1"], x)
    if mixer == "attn":
        h = attn_mod.attention(slot["attn"], h, n_heads=cfg.num_heads,
                               n_kv=cfg.num_kv_heads, head_dim=cfg.resolved_head_dim,
                               rope_theta=cfg.rope_theta, positions=positions,
                               use_flash=use_flash)
    else:
        h = ssm_mod.mamba2_block(slot["ssm"], h, cfg.ssm, cfg.d_model)
    x = x + h
    aux = jnp.zeros((), jnp.float32)
    if ffn_kind != "none":
        h = apply_norm(cfg.norm, slot["norm2"], x)
        if ffn_kind == "moe":
            h, aux = moe_mod.moe_block(slot["moe"], h, cfg.moe, cfg.activation,
                                       spec=spec, phase=phase, layer=layer,
                                       return_aux=True)
        else:
            h = ffn(slot["ffn"], h, cfg.activation)
        x = x + h
    return x, aux


class SlotCache(NamedTuple):
    """Per-slot decode cache — exactly one of kv / ssm is meaningful."""
    kv: Any
    ssm: Any


def _apply_slot_decode(slot, x, cache: SlotCache, cache_len, cfg: ModelConfig,
                       mixer, ffn_kind, *, spec=None, layer=None):
    h = apply_norm(cfg.norm, slot["norm1"], x)
    if mixer == "attn":
        h, new_kv = attn_mod.attention_decode(
            slot["attn"], h, cache.kv, cache_len, n_heads=cfg.num_heads,
            n_kv=cfg.num_kv_heads, head_dim=cfg.resolved_head_dim,
            rope_theta=cfg.rope_theta)
        new_cache = SlotCache(new_kv, cache.ssm)
    else:
        h, new_state = ssm_mod.mamba2_decode(slot["ssm"], h, cache.ssm, cfg.ssm, cfg.d_model)
        new_cache = SlotCache(cache.kv, new_state)
    x = x + h
    if ffn_kind != "none":
        h = apply_norm(cfg.norm, slot["norm2"], x)
        if ffn_kind == "moe":
            h = moe_mod.moe_block(slot["moe"], h, cfg.moe, cfg.activation,
                                  spec=spec, phase="decode", layer=layer)
        else:
            h = ffn(slot["ffn"], h, cfg.activation)
        x = x + h
    return x, new_cache


# ---------------------------------------------------------------------------
# forward (train / scoring)
# ---------------------------------------------------------------------------

def _embed(params, tokens, cfg, prefix_embeds=None):
    x = params["embed"][tokens]                      # (B,S,d) gather
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    return x


def _unembed(params, x, cfg):
    head = params.get("lm_head")
    if head is None:
        head = params["embed"].T
    return x @ head


def forward(params, tokens, cfg: ModelConfig, *, prefix_embeds=None,
            spec=None, use_flash=False, remat=False, unshard=False,
            return_hidden=False):
    """tokens: (B,S) -> (logits (B,S_total,V), aux_loss scalar).

    ``spec``: MoE execution spec (strategy name / dict / ExecutionSpec).
    Per-layer strategy overrides unroll the period scan (each layer may
    lower differently); otherwise layers scan as before.
    ``unshard``: apply the per-layer ZeRO-3 gather constraint inside the
    scan body (FSDP layouts).  ``return_hidden``: skip the unembedding
    (the fused-CE loss path consumes hidden states chunk-wise).
    """
    p, plan = period_plan(cfg)
    sp = _coerce_spec(spec)
    x = _embed(params, tokens, cfg, prefix_embeds)
    S = x.shape[1]
    positions = jnp.arange(S)[None, :]
    # SP residual stream pays off for attention-only stacks; an SSM layer's
    # sequential inter-chunk recurrence would regather the full sequence
    # every layer, so hybrid/ssm families keep the batch-sharded stream
    use_sp = not any(m == "ssm" for m, _ in plan)

    def period_body(carry, period_params, layer_base=None):
        x, aux = carry
        from repro.parallel.sharding import constrain_seq_sharded, unshard_slot_params
        if use_sp:
            x = constrain_seq_sharded(x)
        if unshard:
            period_params = tuple(unshard_slot_params(s) for s in period_params)
        for s, (mixer, ffn_kind) in enumerate(plan):
            layer = None if layer_base is None else layer_base + s
            x, a = _apply_slot_full(period_params[s], x, cfg, mixer, ffn_kind,
                                    positions=positions, spec=sp,
                                    phase="train", layer=layer,
                                    use_flash=use_flash)
            aux = aux + a
        if use_sp:
            x = constrain_seq_sharded(x)   # pin the saved carry to SP layout
        return (x, aux), None

    carry = (x, jnp.zeros((), jnp.float32))
    if _needs_unroll(sp):
        body = period_body
        if remat:
            body = jax.checkpoint(period_body, prevent_cse=False,
                                  static_argnums=(2,))
        for c in range(cfg.num_layers // p):
            pp = jax.tree.map(lambda a: a[c], params["periods"])
            carry, _ = body(carry, pp, c * p)
        x, aux = carry
    else:
        body = period_body
        if remat:
            body = jax.checkpoint(period_body, prevent_cse=False)
        (x, aux), _ = jax.lax.scan(body, carry, params["periods"])
    x = apply_norm(cfg.norm, params["final_norm"], x)
    if return_hidden:
        return x, aux
    return _unembed(params, x, cfg), aux


# ---------------------------------------------------------------------------
# prefill + decode
# ---------------------------------------------------------------------------

def init_caches(cfg: ModelConfig, batch: int, max_seq: int):
    """Stacked per-period SlotCache tuple matching the scan layout."""
    p, plan = period_plan(cfg)
    n_periods = cfg.num_layers // p
    dtype = jnp.dtype(cfg.dtype)
    caches = []
    for mixer, _ in plan:
        if mixer == "attn":
            kv = attn_mod.init_kv_cache(batch, max_seq, cfg.num_kv_heads,
                                        cfg.resolved_head_dim, dtype)
            kv = jax.tree.map(lambda a: jnp.broadcast_to(a, (n_periods,) + a.shape), kv)
            caches.append(SlotCache(kv, ()))
        else:
            st = ssm_mod.init_ssm_state(batch, cfg.d_model, cfg.ssm, dtype)
            st = jax.tree.map(lambda a: jnp.broadcast_to(a, (n_periods,) + a.shape), st)
            caches.append(SlotCache((), st))
    return tuple(caches)


def init_paged_caches(cfg: ModelConfig, batch: int, num_pages: int,
                      page_size: int):
    """Stacked per-period caches with attention KV in pool pages.

    Attention slots hold (n_periods, num_pages, page_size, n_kv, hd)
    physical pages shared by every serving slot through one page table
    (``repro.serving.statepool``); SSM slots keep dense per-row state —
    it is O(1) per slot, so the pool snapshots it by value instead of
    paging it.  Page allocation is in lockstep across layers, so a
    single (B, NP) table indexes every layer's pages."""
    p, plan = period_plan(cfg)
    n_periods = cfg.num_layers // p
    dtype = jnp.dtype(cfg.dtype)
    caches = []
    for mixer, _ in plan:
        if mixer == "attn":
            kv = attn_mod.init_paged_kv_cache(num_pages, page_size,
                                              cfg.num_kv_heads,
                                              cfg.resolved_head_dim, dtype)
            kv = jax.tree.map(lambda a: jnp.broadcast_to(a, (n_periods,) + a.shape), kv)
            caches.append(SlotCache(kv, ()))
        else:
            st = ssm_mod.init_ssm_state(batch, cfg.d_model, cfg.ssm, dtype)
            st = jax.tree.map(lambda a: jnp.broadcast_to(a, (n_periods,) + a.shape), st)
            caches.append(SlotCache((), st))
    return tuple(caches)


def prefill(params, tokens, cfg: ModelConfig, max_seq: int, *,
            prefix_embeds=None, spec=None):
    """Run the prompt, returning (logits, caches filled up to S)."""
    p, plan = period_plan(cfg)
    sp = _coerce_spec(spec)
    x = _embed(params, tokens, cfg, prefix_embeds)
    B, S = x.shape[0], x.shape[1]
    positions = jnp.arange(S)[None, :]

    use_sp = not any(m == "ssm" for m, _ in plan)

    def period_body(x, period_in, layer_base=None):
        from repro.parallel.sharding import constrain_seq_sharded
        if use_sp:
            x = constrain_seq_sharded(x)
        period_params = period_in
        new_caches = []
        for s, (mixer, ffn_kind) in enumerate(plan):
            h = apply_norm(cfg.norm, period_params[s]["norm1"], x)
            if mixer == "attn":
                kv = attn_mod.prefill_kv(period_params[s]["attn"], h,
                                         n_kv=cfg.num_kv_heads,
                                         head_dim=cfg.resolved_head_dim,
                                         rope_theta=cfg.rope_theta, positions=positions)
                # pad cache to max_seq
                pad = max_seq - S
                kv = attn_mod.KVCache(
                    jnp.pad(kv.k, ((0, 0), (0, pad), (0, 0), (0, 0))),
                    jnp.pad(kv.v, ((0, 0), (0, pad), (0, 0), (0, 0))))
                h = attn_mod.attention(period_params[s]["attn"], h,
                                       n_heads=cfg.num_heads, n_kv=cfg.num_kv_heads,
                                       head_dim=cfg.resolved_head_dim,
                                       rope_theta=cfg.rope_theta, positions=positions)
                new_caches.append(SlotCache(kv, ()))
            else:
                h, st = ssm_mod.mamba2_prefill(period_params[s]["ssm"], h, cfg.ssm, cfg.d_model)
                new_caches.append(SlotCache((), st))
            x = x + h
            if ffn_kind != "none":
                h = apply_norm(cfg.norm, period_params[s]["norm2"], x)
                if ffn_kind == "moe":
                    layer = None if layer_base is None else layer_base + s
                    h = moe_mod.moe_block(period_params[s]["moe"], h, cfg.moe,
                                          cfg.activation, spec=sp,
                                          phase="prefill", layer=layer)
                else:
                    h = ffn(period_params[s]["ffn"], h, cfg.activation)
                x = x + h
        return x, tuple(new_caches)

    if _needs_unroll(sp):
        per_period = []
        for c in range(cfg.num_layers // p):
            pp = jax.tree.map(lambda a: a[c], params["periods"])
            x, ncs = period_body(x, pp, c * p)
            per_period.append(ncs)
        caches = jax.tree.map(lambda *xs: jnp.stack(xs), *per_period)
    else:
        x, caches = jax.lax.scan(period_body, x, params["periods"])
    x = apply_norm(cfg.norm, params["final_norm"], x)
    return _unembed(params, x, cfg), caches


def prefill_chunk(params, tokens, caches, cache_len, cfg: ModelConfig, *,
                  spec=None, token_mask=None, return_hidden=False,
                  page_table=None):
    """Append a K-token prompt chunk to existing decode caches.

    The chunked-prefill entry point for continuous-batching serving:
    instead of one monolithic ``prefill`` per prompt, K tokens at a time
    are appended to the per-slot caches, so long prompts never block an
    engine iteration.

    tokens: (B,K) int32; caches: stacked per-period SlotCache tuple from
    ``init_caches``; cache_len: (B,) tokens already cached per row;
    token_mask: (B,K) valid chunk prefix per row (all-False rows pass
    through with their cache bit-untouched — decode-phase and idle slots
    piggyback in the same batch).

    Returns (logits (B,K,V), new_caches, counts); with
    ``return_hidden=True`` the first element is the final-normed hidden
    state (B,K,d) instead — the serving engine reads one position per
    prompt-completing row, so it skips the full (B,K,V) unembed and
    projects just the rows it samples.  ``counts`` is an
    (n_periods, p, E) int32 array of per-layer expert-activation counts
    over the valid tokens (zero rows for non-MoE slots; counts for layer
    L live at ``counts[L // p, L % p]``) — the serving engine's workload
    trace and the chiplet simulator share this feed.  Counts are only
    collected single-process (distributed strategies route their local
    rows inside shard_map).
    """
    p, plan = period_plan(cfg)
    sp = _coerce_spec(spec)
    x = _embed(params, tokens, cfg)
    B, K = tokens.shape
    if token_mask is None:
        token_mask = jnp.ones((B, K), bool)
    E = cfg.moe.num_experts if cfg.moe else 1

    def period_body(x, period_in, layer_base=None):
        from repro.core import gating
        from repro.parallel import meshctx
        period_params, period_caches = period_in
        new_caches = []
        counts = []
        for s, (mixer, ffn_kind) in enumerate(plan):
            h = apply_norm(cfg.norm, period_params[s]["norm1"], x)
            if mixer == "attn":
                if page_table is not None:
                    h, kv = attn_mod.attention_append_paged(
                        period_params[s]["attn"], h, period_caches[s].kv,
                        page_table, cache_len, n_heads=cfg.num_heads,
                        n_kv=cfg.num_kv_heads,
                        head_dim=cfg.resolved_head_dim,
                        rope_theta=cfg.rope_theta, token_mask=token_mask)
                else:
                    h, kv = attn_mod.attention_append(
                        period_params[s]["attn"], h, period_caches[s].kv,
                        cache_len, n_heads=cfg.num_heads,
                        n_kv=cfg.num_kv_heads,
                        head_dim=cfg.resolved_head_dim,
                        rope_theta=cfg.rope_theta, token_mask=token_mask)
                new_caches.append(SlotCache(kv, period_caches[s].ssm))
            else:
                h, st = ssm_mod.mamba2_chunk(
                    period_params[s]["ssm"], h, period_caches[s].ssm,
                    cfg.ssm, cfg.d_model, token_mask=token_mask)
                new_caches.append(SlotCache(period_caches[s].kv, st))
            x = x + h
            cnt = jnp.zeros((E,), jnp.int32)
            if ffn_kind != "none":
                h = apply_norm(cfg.norm, period_params[s]["norm2"], x)
                if ffn_kind == "moe":
                    layer = None if layer_base is None else layer_base + s
                    routing = None
                    if meshctx.get_mesh() is None:
                        # route ONCE: the same Routing feeds the trace
                        # counts and the expert execution
                        routing = gating.route(
                            period_params[s]["moe"]["router"],
                            h.reshape(-1, h.shape[-1]), top_k=cfg.moe.top_k)
                        cnt = gating.expert_token_counts(
                            routing, token_mask.reshape(-1)).astype(jnp.int32)
                    h = moe_mod.moe_block(period_params[s]["moe"], h, cfg.moe,
                                          cfg.activation, spec=sp,
                                          phase="prefill", layer=layer,
                                          routing=routing)
                else:
                    h = ffn(period_params[s]["ffn"], h, cfg.activation)
                x = x + h
            counts.append(cnt)
        return x, (tuple(new_caches), jnp.stack(counts))

    if _needs_unroll(sp):
        per_period, per_counts = [], []
        for c in range(cfg.num_layers // p):
            pin = jax.tree.map(lambda a: a[c], (params["periods"], caches))
            x, (ncs, cnt) = period_body(x, pin, c * p)
            per_period.append(ncs)
            per_counts.append(cnt)
        new_caches = jax.tree.map(lambda *xs: jnp.stack(xs), *per_period)
        counts = jnp.stack(per_counts)
    else:
        x, (new_caches, counts) = jax.lax.scan(
            period_body, x, (params["periods"], caches))
    x = apply_norm(cfg.norm, params["final_norm"], x)
    if return_hidden:
        return x, new_caches, counts
    return _unembed(params, x, cfg), new_caches, counts


# ---------------------------------------------------------------------------
# serving decode segments (masked per-layer sub-steps)
# ---------------------------------------------------------------------------
#
# The serving engine executes the network layer by layer so Algorithm 2
# can defer requests exactly at MoE boundaries.  These entry points are
# the single source of truth for that per-layer math: the engine's
# legacy eager loop calls them one layer at a time, and the fused
# mega-steps (repro.serving.megastep) trace the same functions into one
# compiled segment per MoE-boundary span — bit-identical by
# construction.  All row selection is by boolean (B,) masks realized as
# jnp.where merges, so an all-False mask is a bitwise no-op (matching
# the eager loop's skip).

_PLAN_CACHE: dict = {}


def cached_period_plan(cfg: ModelConfig):
    """Memoized :func:`period_plan` (configs are frozen dataclasses;
    unhashable ones fall through to the direct computation)."""
    try:
        hit = _PLAN_CACHE.get(cfg)
    except TypeError:                      # unhashable config
        return period_plan(cfg)
    if hit is None:
        hit = _PLAN_CACHE[cfg] = period_plan(cfg)
    return hit


def _layer_slot(params, layer: int, p: int):
    """Parameters of one absolute layer out of the period-stacked tree."""
    period_idx, slot = divmod(layer, p)
    return jax.tree.map(lambda a: a[period_idx], params["periods"][slot])


def decode_embed_merge(params, x, token_vec, start_mask, cfg: ModelConfig):
    """Embed the fresh tokens of rows starting a new pass; other rows
    keep their carried residual stream.  token_vec: (B,) int."""
    emb = params["embed"][jnp.asarray(token_vec)][:, None, :]
    return jnp.where(jnp.asarray(start_mask)[:, None, None], emb, x)


def decode_mixer(params, x, caches, cache_len, cfg: ModelConfig,
                 layer: int, mask, page_table=None):
    """Masked one-token mixer (attention / SSM) step for one layer.

    Only ``mask`` rows advance: their cache entry and residual stream
    update; everything else is bit-untouched.  Returns (x, caches) with
    the full stacked cache tuple rebuilt functionally.  With a
    ``page_table`` (B, NP), attention layers read/write through the
    paged state pool (the scatter applies the row mask itself — masked
    rows are dropped out of range, so the merge below is skipped).
    """
    p, plan = cached_period_plan(cfg)
    mixer, _ = plan[layer % p]
    period_idx, slot_i = divmod(layer, p)
    slot = _layer_slot(params, layer, p)
    mask = jnp.asarray(mask)
    h = apply_norm(cfg.norm, slot["norm1"], x)
    if mixer == "attn" and page_table is not None:
        pages = jax.tree.map(lambda a: a[period_idx], caches[slot_i].kv)
        h, new_pages = attn_mod.attention_decode_paged(
            slot["attn"], h, pages, page_table, cache_len,
            n_heads=cfg.num_heads, n_kv=cfg.num_kv_heads,
            head_dim=cfg.resolved_head_dim, rope_theta=cfg.rope_theta,
            row_mask=mask)
        new_stack = jax.tree.map(lambda st, n: st.at[period_idx].set(n),
                                 caches[slot_i].kv, new_pages)
        caches = tuple(c if i != slot_i else SlotCache(new_stack, c.ssm)
                       for i, c in enumerate(caches))
        return jnp.where(mask[:, None, None], x + h, x), caches
    cache = jax.tree.map(lambda a: a[period_idx], caches[slot_i])
    if mixer == "attn":
        h, new_kv = attn_mod.attention_decode(
            slot["attn"], h, cache.kv, cache_len,
            n_heads=cfg.num_heads, n_kv=cfg.num_kv_heads,
            head_dim=cfg.resolved_head_dim, rope_theta=cfg.rope_theta)
        new_cache = SlotCache(new_kv, cache.ssm)
    else:
        h, new_state = ssm_mod.mamba2_decode(slot["ssm"], h, cache.ssm,
                                             cfg.ssm, cfg.d_model)
        new_cache = SlotCache(cache.kv, new_state)

    # masked cache update (only active slots advance)
    def upd(old_stack, old, new):
        if not hasattr(new, "ndim") or new.ndim == 0:
            return old_stack
        m = mask.reshape((-1,) + (1,) * (new.ndim - 1))
        merged = jnp.where(m, new, old)
        return old_stack.at[period_idx].set(merged)

    caches = tuple(
        c if i != slot_i else jax.tree.map(
            lambda stack, o, n: upd(stack, o, n), caches[slot_i], cache,
            new_cache)
        for i, c in enumerate(caches))
    return jnp.where(mask[:, None, None], x + h, x), caches


def decode_route(params, x, cfg: ModelConfig, layer: int, count_mask=None):
    """Pipeline *route* stage at one MoE boundary: normed activations +
    Routing for every slot row (routed once — the same Routing feeds
    deferral, the workload trace, and the expert execution).  With a
    ``count_mask`` the per-expert token counts over those rows are
    computed in-graph too (the fused path fetches them in one transfer
    instead of a separate eager count pass)."""
    from repro.core import gating
    p, _ = cached_period_plan(cfg)
    slot = _layer_slot(params, layer, p)
    h = apply_norm(cfg.norm, slot["norm2"], x)
    routing = gating.route(slot["moe"]["router"], h[:, 0, :],
                           top_k=cfg.moe.top_k)
    counts = None
    if count_mask is not None:
        counts = gating.expert_token_counts(routing,
                                            jnp.asarray(count_mask))
    return h, routing, counts


def decode_moe_exec(params, x, h, routing, cfg: ModelConfig, layer: int,
                    mask, *, spec=None, schedule=None):
    """Dispatch + combine stages at one MoE boundary: execute the
    experts on the already-routed activations (along the EMA trajectory
    when ``schedule`` is dynamic) and merge the masked residual."""
    p, _ = cached_period_plan(cfg)
    slot = _layer_slot(params, layer, p)
    mask = jnp.asarray(mask)
    h = moe_mod.moe_block(slot["moe"], h, cfg.moe, cfg.activation,
                          spec=spec, phase="decode", layer=layer,
                          routing=routing, schedule=schedule)
    return jnp.where(mask[:, None, None], x + h, x)


def decode_ffn(params, x, cfg: ModelConfig, layer: int, mask):
    """Masked dense-FFN sub-step (no-op for ffn_kind == 'none')."""
    p, plan = cached_period_plan(cfg)
    _, ffn_kind = plan[layer % p]
    if ffn_kind == "none":
        return x
    slot = _layer_slot(params, layer, p)
    mask = jnp.asarray(mask)
    h = apply_norm(cfg.norm, slot["norm2"], x)
    h = ffn(slot["ffn"], h, cfg.activation)
    return jnp.where(mask[:, None, None], x + h, x)


def decode_span(params, x, caches, cache_len, cfg: ModelConfig,
                lo: int, hi: int, mask, page_table=None):
    """Run the non-MoE layers ``[lo, hi)`` (mixer + dense FFN each) for
    the masked rows — the body of one mega-step segment between MoE
    boundaries (which must not contain an MoE layer)."""
    p, plan = cached_period_plan(cfg)
    for layer in range(lo, hi):
        assert plan[layer % p][1] != "moe", \
            f"layer {layer} is an MoE boundary, not span interior"
        x, caches = decode_mixer(params, x, caches, cache_len, cfg,
                                 layer, mask, page_table=page_table)
        x = decode_ffn(params, x, cfg, layer, mask)
    return x, caches


def decode_logits(params, x, cfg: ModelConfig):
    """Final norm + unembed of the carried (B,1,d) residual stream."""
    h = apply_norm(cfg.norm, params["final_norm"], x)
    return _unembed(params, h, cfg)


def decode_step(params, token, caches, cache_len, cfg: ModelConfig, *,
                spec=None, unshard=False):
    """token: (B,1) int32; caches from init_caches/prefill; cache_len: (B,).

    Returns (logits (B,1,V), new caches).
    """
    p, plan = period_plan(cfg)
    sp = _coerce_spec(spec)
    x = _embed(params, token, cfg)

    def period_body(x, period_in, layer_base=None):
        period_params, period_caches = period_in
        if unshard:
            from repro.parallel.sharding import unshard_slot_params
            period_params = tuple(unshard_slot_params(s) for s in period_params)
        new_caches = []
        for s, (mixer, ffn_kind) in enumerate(plan):
            layer = None if layer_base is None else layer_base + s
            x, nc = _apply_slot_decode(period_params[s], x, period_caches[s],
                                       cache_len, cfg, mixer, ffn_kind,
                                       spec=sp, layer=layer)
            new_caches.append(nc)
        return x, tuple(new_caches)

    if _needs_unroll(sp):
        per_period = []
        for c in range(cfg.num_layers // p):
            pin = jax.tree.map(lambda a: a[c], (params["periods"], caches))
            x, ncs = period_body(x, pin, c * p)
            per_period.append(ncs)
        new_caches = jax.tree.map(lambda *xs: jnp.stack(xs), *per_period)
    else:
        x, new_caches = jax.lax.scan(period_body, x, (params["periods"], caches))
    x = apply_norm(cfg.norm, params["final_norm"], x)
    return _unembed(params, x, cfg), new_caches
