"""Primitive layers: norms, embeddings, rotary, linear init helpers.

All layers are pure functions over parameter pytrees (nested dicts of
``jnp.ndarray``).  Compute-sensitive reductions run in fp32 and cast
back to the working dtype.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def _dtype(cfg_dtype: str):
    return jnp.dtype(cfg_dtype)


# ---------------------------------------------------------------------------
# Init helpers
# ---------------------------------------------------------------------------

def dense_init(key, d_in, d_out, dtype, scale: float | None = None):
    scale = scale if scale is not None else 1.0 / np.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab, d, dtype):
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm_init(d):
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(params, x, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * params["scale"]
    return y.astype(x.dtype)


def layernorm_init(d):
    return {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)}


def layernorm(params, x, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps) * params["scale"] + params["bias"]
    return y.astype(x.dtype)


def norm_init(kind: str, d):
    return rmsnorm_init(d) if kind == "rmsnorm" else layernorm_init(d)


def apply_norm(kind: str, params, x):
    return rmsnorm(params, x) if kind == "rmsnorm" else layernorm(params, x)


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., seq, heads, head_dim); positions: (..., seq) int32."""
    hd = x.shape[-1]
    inv = jnp.asarray(rope_freqs(hd, theta))          # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * inv   # (..., seq, hd/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(length: int, d: int):
    pos = np.arange(length)[:, None]
    dim = np.arange(d)[None, :]
    ang = pos / np.power(10000, 2 * (dim // 2) / d)
    enc = np.where(dim % 2 == 0, np.sin(ang), np.cos(ang))
    return jnp.asarray(enc, jnp.float32)


# ---------------------------------------------------------------------------
# Activations
# ---------------------------------------------------------------------------

def activation_fn(kind: str):
    if kind == "swiglu":  # handled in mlp.py (two projections)
        return jax.nn.silu
    if kind == "relu2":
        return lambda x: jnp.square(jax.nn.relu(x))
    if kind == "gelu":
        return jax.nn.gelu
    raise ValueError(kind)
