"""Whisper-style encoder-decoder backbone (conv frontend stubbed).

``input_specs`` supplies precomputed frame embeddings (B, F, d) — the
conv1d×2 mel frontend is a stub per the assignment.  Encoder layers are
bidirectional self-attn + GELU FFN; decoder layers add causal self-attn
with KV cache and cross-attention over the encoder memory.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from . import attention as attn_mod
from .layers import embed_init, norm_init, apply_norm, sinusoidal_positions
from .mlp import ffn_init, ffn


def _enc_layer_init(key, cfg, dtype):
    ks = jax.random.split(key, 2)
    return {
        "norm1": norm_init(cfg.norm, cfg.d_model),
        "attn": attn_mod.attn_init(ks[0], cfg.d_model, cfg.num_heads,
                                   cfg.num_kv_heads, cfg.resolved_head_dim, dtype),
        "norm2": norm_init(cfg.norm, cfg.d_model),
        "ffn": ffn_init(ks[1], cfg.d_model, cfg.d_ff, cfg.activation, dtype),
    }


def _dec_layer_init(key, cfg, dtype):
    ks = jax.random.split(key, 3)
    return {
        "norm1": norm_init(cfg.norm, cfg.d_model),
        "self_attn": attn_mod.attn_init(ks[0], cfg.d_model, cfg.num_heads,
                                        cfg.num_kv_heads, cfg.resolved_head_dim, dtype),
        "norm_x": norm_init(cfg.norm, cfg.d_model),
        "cross_attn": attn_mod.attn_init(ks[1], cfg.d_model, cfg.num_heads,
                                         cfg.num_kv_heads, cfg.resolved_head_dim, dtype),
        "norm2": norm_init(cfg.norm, cfg.d_model),
        "ffn": ffn_init(ks[2], cfg.d_model, cfg.d_ff, cfg.activation, dtype),
    }


def init_encdec(key, cfg: ModelConfig):
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, cfg.encoder_layers + cfg.num_layers + 2)
    enc = [_enc_layer_init(ks[i], cfg, dtype) for i in range(cfg.encoder_layers)]
    dec = [_dec_layer_init(ks[cfg.encoder_layers + i], cfg, dtype)
           for i in range(cfg.num_layers)]
    return {
        "embed": embed_init(ks[-1], cfg.vocab_size, cfg.d_model, dtype),
        "enc_layers": jax.tree.map(lambda *xs: jnp.stack(xs), *enc),
        "dec_layers": jax.tree.map(lambda *xs: jnp.stack(xs), *dec),
        "enc_norm": norm_init(cfg.norm, cfg.d_model),
        "dec_norm": norm_init(cfg.norm, cfg.d_model),
    }


def encode(params, frames, cfg: ModelConfig, remat: bool = False):
    """frames: (B, F, d) precomputed embeddings -> encoder memory (B, F, d)."""
    F = frames.shape[1]
    x = frames + sinusoidal_positions(F, cfg.d_model).astype(frames.dtype)[None]

    def body(x, lp):
        h = apply_norm(cfg.norm, lp["norm1"], x)
        h = attn_mod.attention(lp["attn"], h, n_heads=cfg.num_heads,
                               n_kv=cfg.num_kv_heads, head_dim=cfg.resolved_head_dim,
                               rope_theta=0.0, causal=False)
        x = x + h
        h = apply_norm(cfg.norm, lp["norm2"], x)
        x = x + ffn(lp["ffn"], h, cfg.activation)
        return x, None

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return apply_norm(cfg.norm, params["enc_norm"], x)


def decode_train(params, tokens, memory, cfg: ModelConfig,
                 remat: bool = False, return_hidden: bool = False):
    """Teacher-forced decoder. tokens: (B,S); memory: (B,F,d) -> logits
    (or final hidden states when ``return_hidden``)."""
    S = tokens.shape[1]
    x = params["embed"][tokens]
    x = x + sinusoidal_positions(S, cfg.d_model).astype(x.dtype)[None]

    def body(x, lp):
        h = apply_norm(cfg.norm, lp["norm1"], x)
        h = attn_mod.attention(lp["self_attn"], h, n_heads=cfg.num_heads,
                               n_kv=cfg.num_kv_heads, head_dim=cfg.resolved_head_dim,
                               rope_theta=0.0, causal=True)
        x = x + h
        h = apply_norm(cfg.norm, lp["norm_x"], x)
        h = attn_mod.cross_attention(lp["cross_attn"], h, memory,
                                     n_heads=cfg.num_heads, n_kv=cfg.num_kv_heads,
                                     head_dim=cfg.resolved_head_dim)
        x = x + h
        h = apply_norm(cfg.norm, lp["norm2"], x)
        x = x + ffn(lp["ffn"], h, cfg.activation)
        return x, None

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["dec_layers"])
    x = apply_norm(cfg.norm, params["dec_norm"], x)
    if return_hidden:
        return x
    return x @ params["embed"].T


class EncDecCaches(NamedTuple):
    self_kv: attn_mod.KVCache      # stacked (L, B, S, kv, hd)
    cross_k: jnp.ndarray           # (L, B, F, kv, hd) precomputed from memory
    cross_v: jnp.ndarray


def init_decode_caches(params, memory, cfg: ModelConfig, batch, max_seq):
    """Precompute cross-attn K/V from memory; empty self-attn cache."""
    dtype = jnp.dtype(cfg.dtype)
    kv = attn_mod.init_kv_cache(batch, max_seq, cfg.num_kv_heads,
                                cfg.resolved_head_dim, dtype)
    L = cfg.num_layers
    kv = jax.tree.map(lambda a: jnp.broadcast_to(a, (L,) + a.shape), kv)

    def per_layer(lp):
        k = (memory @ lp["cross_attn"]["wk"]).reshape(
            memory.shape[0], memory.shape[1], cfg.num_kv_heads, cfg.resolved_head_dim)
        v = (memory @ lp["cross_attn"]["wv"]).reshape(
            memory.shape[0], memory.shape[1], cfg.num_kv_heads, cfg.resolved_head_dim)
        return k, v

    ck, cv = jax.vmap(per_layer)(params["dec_layers"])
    return EncDecCaches(kv, ck, cv)


def decode_step(params, token, caches: EncDecCaches, cache_len, cfg: ModelConfig):
    """token: (B,1) -> (logits (B,1,V), new caches)."""
    B = token.shape[0]
    x = params["embed"][token]
    pos_table = sinusoidal_positions(caches.self_kv.k.shape[2], cfg.d_model)
    x = x + pos_table[jnp.minimum(cache_len, pos_table.shape[0] - 1)][:, None].astype(x.dtype)

    def body(x, layer_in):
        lp, kv, ck, cv = layer_in
        h = apply_norm(cfg.norm, lp["norm1"], x)
        h, new_kv = attn_mod.attention_decode(
            lp["self_attn"], h, kv, cache_len, n_heads=cfg.num_heads,
            n_kv=cfg.num_kv_heads, head_dim=cfg.resolved_head_dim, rope_theta=0.0)
        x = x + h
        # cross attention against precomputed K/V
        h = apply_norm(cfg.norm, lp["norm_x"], x)
        q = (h @ lp["cross_attn"]["wq"]).reshape(B, 1, cfg.num_heads, cfg.resolved_head_dim)
        kf = attn_mod._repeat_kv(ck, cfg.num_heads)
        vf = attn_mod._repeat_kv(cv, cfg.num_heads)
        scores = jnp.einsum("bqhd,bkhd->bhqk", q, kf).astype(jnp.float32)
        scores = scores / jnp.sqrt(jnp.float32(cfg.resolved_head_dim))
        probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
        o = jnp.einsum("bhqk,bkhd->bqhd", probs, vf).reshape(B, 1, -1)
        x = x + o @ lp["cross_attn"]["wo"]
        h = apply_norm(cfg.norm, lp["norm2"], x)
        x = x + ffn(lp["ffn"], h, cfg.activation)
        return x, new_kv

    x, new_kv = jax.lax.scan(
        body, x, (params["dec_layers"], caches.self_kv, caches.cross_k, caches.cross_v))
    x = apply_norm(cfg.norm, params["dec_norm"], x)
    logits = x @ params["embed"].T
    return logits, EncDecCaches(new_kv, caches.cross_k, caches.cross_v)
