from . import api, attention, layers, mamba2, mlp, moe, transformer, whisper
from .api import init_params, loss_fn, prefill_fn, decode_fn, init_decode_caches
